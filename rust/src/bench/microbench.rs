//! Microbenchmarks: per-executable latency for the building blocks of a
//! cycle (verify at each M, drafter calls). These are the numbers the
//! §Perf analysis in EXPERIMENTS.md is built from: FastEagle's win is
//! 1 drafter call/cycle vs EAGLE's N, and this shows the per-call cost.
//!
//! On the interpreter backend this also runs the compiled-plan kernel
//! suite (dot / reduce / fused elementwise) against the naive reference
//! evaluator and writes `bench_out/BENCH_interp_point.json` — the point
//! CI's microbench lane validates against the committed
//! `BENCH_interp.json` trajectory.

use std::rc::Rc;
use std::time::Instant;

use anyhow::Result;

use crate::draft::{make_drafter, ObserveArgs};
use crate::model::{MaskRow, TargetModel};
use crate::spec::Sampler;
use crate::util::json::Json;
use crate::util::stats::summarize;

use super::harness::{has_weights, render_table, write_report, BenchEnv};

const TARGET: &str = "base";

fn time_loop(mut f: impl FnMut() -> Result<()>, iters: usize) -> Result<Vec<f64>> {
    // warmup (compiles)
    f()?;
    f()?;
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f()?;
        out.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(out)
}

pub fn run(env: &BenchEnv) -> Result<()> {
    let iters = if env.quick { 10 } else { 40 };
    let store = env.store(TARGET)?;
    let tm = TargetModel::open(Rc::clone(&store))?;
    let spec = tm.spec.clone();
    let mut rows = Vec::new();
    let mut report = Vec::new();

    // target verify at each lowered M
    for &m in &spec.verify_ms {
        let mut kv = tm.new_kv()?;
        // small prefix
        let prompt: Vec<i32> = (0..32).map(|i| (65 + (i % 26)) as i32).collect();
        tm.prefill(&mut kv, &prompt)?;
        let base_len = kv.len(0);
        let tokens: Vec<i32> = (0..m).map(|i| (97 + (i % 26)) as i32).collect();
        let positions: Vec<i32> = (0..m).map(|i| (base_len + i) as i32).collect();
        let rows_m: Vec<MaskRow> = (0..m)
            .map(|i| MaskRow {
                prefix_upto: base_len,
                extra: (0..=i).map(|j| base_len + j).collect(),
            })
            .collect();
        let samples = time_loop(
            || {
                let mut kv2 = kv.clone();
                tm.step(&mut kv2, &tokens, &positions, &rows_m)?;
                Ok(())
            },
            iters,
        )?;
        let s = summarize(&samples);
        rows.push(vec![
            format!("tgt_m{m}"),
            format!("{:.2}", s.mean),
            format!("{:.2}", s.p50),
            format!("{:.2}", s.p99),
        ]);
        report.push(Json::obj(vec![
            ("exec", Json::str(&format!("tgt_m{m}"))),
            ("mean_ms", Json::num(s.mean)),
            ("p50_ms", Json::num(s.p50)),
        ]));
    }

    // drafter cycle cost: observe(1 anchor) + draft
    for dn in ["fasteagle", "eagle3", "medusa", "sps"] {
        if !has_weights(env, TARGET, dn) {
            continue;
        }
        let mut dr = make_drafter(Rc::clone(&store), dn)?;
        dr.reset()?;
        let fd = spec.feat_dim;
        let feats = vec![0.1f32; fd * 4];
        let anchors = vec![65i32, 66, 67, 68];
        let nexts = vec![66i32, 67, 68, 69];
        dr.observe(ObserveArgs {
            feats: &feats,
            anchor_tokens: &anchors,
            next_tokens: &nexts,
            first_pos: 0,
        })?;
        let mut pos = 4usize;
        let mut sampler = Sampler::new(0.0, 1);
        let samples = time_loop(
            || {
                // one cycle's drafter work: observe(2 anchors) + draft
                let f2 = vec![0.1f32; fd * 2];
                dr.observe(ObserveArgs {
                    feats: &f2,
                    anchor_tokens: &[70, 71],
                    next_tokens: &[71, 72],
                    first_pos: pos,
                })?;
                pos += 2;
                if pos > spec.max_seq - 16 {
                    dr.reset()?;
                    pos = 0;
                    dr.observe(ObserveArgs {
                        feats: &feats,
                        anchor_tokens: &anchors,
                        next_tokens: &nexts,
                        first_pos: 0,
                    })?;
                    pos = 4;
                }
                // unbounded levels: measure the drafter's full native cost
                let out = dr.draft(72, pos - 1, 0.0, usize::MAX)?;
                let _ = &out;
                let _ = sampler.coin();
                Ok(())
            },
            iters,
        )?;
        let s = summarize(&samples);
        rows.push(vec![
            format!("draft[{dn}]"),
            format!("{:.2}", s.mean),
            format!("{:.2}", s.p50),
            format!("{:.2}", s.p99),
        ]);
        report.push(Json::obj(vec![
            ("exec", Json::str(&format!("draft[{dn}]"))),
            ("mean_ms", Json::num(s.mean)),
            ("p50_ms", Json::num(s.p50)),
        ]));
    }

    // interpreter kernel suite: the compiled-plan path measured against
    // the naive reference evaluator on the same module. The plan is the
    // production path (`backend::interp` compiles one per executable);
    // `evaluate` stays in-tree as the bit-identical reference, so the
    // speedup column is a live regression gate, not a one-off claim.
    if env.runtime.kind() == crate::backend::BackendKind::Interpret {
        use crate::backend::hlo::builder::{HloBuilder, Ty};
        use crate::backend::hlo::eval::{evaluate, Value};
        use crate::backend::hlo::parser::parse_module;
        use crate::backend::hlo::plan::{EvalOptions, ExecPlan, OpTimes};
        use std::sync::Arc;

        struct Case {
            name: String,
            text: String,
            args: Vec<Arc<Value>>,
        }
        let mut cases: Vec<Case> = Vec::new();

        // last-axis reduce rows (add + max over one operand)
        for &(rows_n, k) in &[(256usize, 512usize), (1024, 256)] {
            let mut hb = HloBuilder::new("redbench");
            let p = hb.param(Ty::F32, vec![rows_n, k]);
            let s = hb.reduce_add(&p, &[1]);
            let mx = hb.reduce_max(&p, &[1]);
            cases.push(Case {
                name: format!("interp_reduce_{rows_n}x{k}"),
                text: hb.finish(&[&s, &mx]),
                args: vec![Arc::new(Value::f32(vec![rows_n, k], vec![0.5; rows_n * k]))],
            });
        }
        // square-ish GEMMs plus the fixture target's logit GEMM shapes:
        // [B*M, d_model=16] x [d_model, vocab=272] at (M=8, B=1) and
        // (M=16, B=4) — the matmul every verify step pays
        for &(name, m, k, n) in &[
            ("interp_dot_32x64x64", 32usize, 64usize, 64usize),
            ("interp_dot_128x128x128", 128, 128, 128),
            ("interp_dot_tgt_m8_b1", 8, 16, 272),
            ("interp_dot_tgt_m16_b4", 64, 16, 272),
        ] {
            let mut hb = HloBuilder::new("dotbench");
            let pa = hb.param(Ty::F32, vec![m, k]);
            let pb = hb.param(Ty::F32, vec![k, n]);
            let c = hb.matmul(&pa, &pb);
            cases.push(Case {
                name: name.to_string(),
                text: hb.finish(&[&c]),
                args: vec![
                    Arc::new(Value::f32(vec![m, k], vec![0.5; m * k])),
                    Arc::new(Value::f32(vec![k, n], vec![0.25; k * n])),
                ],
            });
        }
        // elementwise chain the fusion pass collapses into one loop:
        // compare/select/exp/tanh/mul over splat constants
        {
            let (rows_n, k) = (256usize, 512usize);
            let mut hb = HloBuilder::new("fusebench");
            let x = hb.param(Ty::F32, vec![rows_n, k]);
            let half = hb.const_f32(0.5);
            let sp = hb.splat(&half, vec![rows_n, k]);
            let p = hb.compare(&x, &sp, "GT");
            let xm = hb.mul(&x, &sp);
            let e = hb.exp(&xm);
            let t = hb.tanh(&x);
            let sel = hb.select(&p, &e, &t);
            let out = hb.mul(&sel, &sp);
            cases.push(Case {
                name: format!("interp_fuse_{rows_n}x{k}"),
                text: hb.finish(&[&out]),
                args: vec![Arc::new(Value::f32(vec![rows_n, k], vec![0.3; rows_n * k]))],
            });
        }

        let opts = EvalOptions::from_env();
        let mut interp_rows = Vec::new();
        let mut point_cells = Vec::new();
        let mut gate_speedups = Vec::new();
        for case in &cases {
            let module = Arc::new(parse_module(&case.text)?);
            let plan = ExecPlan::compile(&module, opts)?;
            let samples = time_loop(
                || {
                    let _ = plan.execute(&case.args)?;
                    Ok(())
                },
                iters,
            )?;
            let s = summarize(&samples);
            let ref_samples = time_loop(
                || {
                    let _ = evaluate(&module, &case.args)?;
                    Ok(())
                },
                iters,
            )?;
            let rs = summarize(&ref_samples);
            let speedup = rs.mean / s.mean.max(1e-9);
            // one timed run for the per-op-kind attribution
            let mut times = OpTimes::new();
            let _ = plan.execute_timed(&case.args, &mut times)?;
            let per_op = Json::Obj(
                times
                    .iter()
                    .map(|(k, t)| (k.to_string(), Json::num(t.total_ns as f64 / 1e3)))
                    .collect(),
            );
            rows.push(vec![
                case.name.clone(),
                format!("{:.2}", s.mean),
                format!("{:.2}", s.p50),
                format!("{:.2}", s.p99),
            ]);
            interp_rows.push(vec![
                case.name.clone(),
                format!("{:.3}", s.mean),
                format!("{:.3}", rs.mean),
                format!("{:.2}x", speedup),
            ]);
            report.push(Json::obj(vec![
                ("exec", Json::str(&case.name)),
                ("mean_ms", Json::num(s.mean)),
                ("p50_ms", Json::num(s.p50)),
            ]));
            if case.name.starts_with("interp_dot_") || case.name.starts_with("interp_reduce_") {
                gate_speedups.push(speedup);
            }
            point_cells.push(Json::obj(vec![
                ("exec", Json::str(&case.name)),
                ("mean_ms", Json::num(s.mean)),
                ("p50_ms", Json::num(s.p50)),
                ("ref_mean_ms", Json::num(rs.mean)),
                ("speedup", Json::num(speedup)),
                ("per_op_us", per_op),
            ]));
        }
        let geomean = if gate_speedups.is_empty() {
            0.0
        } else {
            (gate_speedups.iter().map(|s| s.ln()).sum::<f64>() / gate_speedups.len() as f64).exp()
        };
        println!("\n=== Interpreter plan vs naive reference (ms) ===");
        let h: Vec<String> =
            ["exec", "plan", "naive", "speedup"].iter().map(|s| s.to_string()).collect();
        println!("{}", render_table(&h, &interp_rows));
        println!(
            "geomean speedup over interp_dot_*/interp_reduce_*: {geomean:.2}x \
             (threads={}, fuse={})",
            opts.threads, opts.fuse
        );
        let point = Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("bench", Json::str("interp_micro")),
            ("quick", Json::Bool(env.quick)),
            ("backend", Json::str("interpret")),
            ("threads", Json::num(opts.threads as f64)),
            ("fuse", Json::Bool(opts.fuse)),
            ("geomean_speedup", Json::num(geomean)),
            ("cells", Json::Arr(point_cells)),
        ]);
        let ppath = write_report("BENCH_interp_point", &point)?;
        println!("interp point -> {ppath:?}");
    }

    println!("\n=== Microbench (per-call latency, ms) ===");
    let headers: Vec<String> =
        ["op", "mean", "p50", "p99"].iter().map(|s| s.to_string()).collect();
    println!("{}", render_table(&headers, &rows));
    let path = write_report("microbench", &Json::Arr(report))?;
    println!("report -> {path:?}");
    Ok(())
}
