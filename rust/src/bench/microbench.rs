//! Microbenchmarks: per-executable latency for the building blocks of a
//! cycle (verify at each M, drafter calls). These are the numbers the
//! §Perf analysis in EXPERIMENTS.md is built from: FastEagle's win is
//! 1 drafter call/cycle vs EAGLE's N, and this shows the per-call cost.

use std::rc::Rc;
use std::time::Instant;

use anyhow::Result;

use crate::draft::{make_drafter, ObserveArgs};
use crate::model::{MaskRow, TargetModel};
use crate::spec::Sampler;
use crate::util::json::Json;
use crate::util::stats::summarize;

use super::harness::{has_weights, render_table, write_report, BenchEnv};

const TARGET: &str = "base";

fn time_loop(mut f: impl FnMut() -> Result<()>, iters: usize) -> Result<Vec<f64>> {
    // warmup (compiles)
    f()?;
    f()?;
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f()?;
        out.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(out)
}

pub fn run(env: &BenchEnv) -> Result<()> {
    let iters = if env.quick { 10 } else { 40 };
    let store = env.store(TARGET)?;
    let tm = TargetModel::open(Rc::clone(&store))?;
    let spec = tm.spec.clone();
    let mut rows = Vec::new();
    let mut report = Vec::new();

    // target verify at each lowered M
    for &m in &spec.verify_ms {
        let mut kv = tm.new_kv()?;
        // small prefix
        let prompt: Vec<i32> = (0..32).map(|i| (65 + (i % 26)) as i32).collect();
        tm.prefill(&mut kv, &prompt)?;
        let base_len = kv.len(0);
        let tokens: Vec<i32> = (0..m).map(|i| (97 + (i % 26)) as i32).collect();
        let positions: Vec<i32> = (0..m).map(|i| (base_len + i) as i32).collect();
        let rows_m: Vec<MaskRow> = (0..m)
            .map(|i| MaskRow {
                prefix_upto: base_len,
                extra: (0..=i).map(|j| base_len + j).collect(),
            })
            .collect();
        let samples = time_loop(
            || {
                let mut kv2 = kv.clone();
                tm.step(&mut kv2, &tokens, &positions, &rows_m)?;
                Ok(())
            },
            iters,
        )?;
        let s = summarize(&samples);
        rows.push(vec![
            format!("tgt_m{m}"),
            format!("{:.2}", s.mean),
            format!("{:.2}", s.p50),
            format!("{:.2}", s.p99),
        ]);
        report.push(Json::obj(vec![
            ("exec", Json::str(&format!("tgt_m{m}"))),
            ("mean_ms", Json::num(s.mean)),
            ("p50_ms", Json::num(s.p50)),
        ]));
    }

    // drafter cycle cost: observe(1 anchor) + draft
    for dn in ["fasteagle", "eagle3", "medusa", "sps"] {
        if !has_weights(env, TARGET, dn) {
            continue;
        }
        let mut dr = make_drafter(Rc::clone(&store), dn)?;
        dr.reset()?;
        let fd = spec.feat_dim;
        let feats = vec![0.1f32; fd * 4];
        let anchors = vec![65i32, 66, 67, 68];
        let nexts = vec![66i32, 67, 68, 69];
        dr.observe(ObserveArgs {
            feats: &feats,
            anchor_tokens: &anchors,
            next_tokens: &nexts,
            first_pos: 0,
        })?;
        let mut pos = 4usize;
        let mut sampler = Sampler::new(0.0, 1);
        let samples = time_loop(
            || {
                // one cycle's drafter work: observe(2 anchors) + draft
                let f2 = vec![0.1f32; fd * 2];
                dr.observe(ObserveArgs {
                    feats: &f2,
                    anchor_tokens: &[70, 71],
                    next_tokens: &[71, 72],
                    first_pos: pos,
                })?;
                pos += 2;
                if pos > spec.max_seq - 16 {
                    dr.reset()?;
                    pos = 0;
                    dr.observe(ObserveArgs {
                        feats: &feats,
                        anchor_tokens: &anchors,
                        next_tokens: &nexts,
                        first_pos: 0,
                    })?;
                    pos = 4;
                }
                // unbounded levels: measure the drafter's full native cost
                let out = dr.draft(72, pos - 1, 0.0, usize::MAX)?;
                let _ = &out;
                let _ = sampler.coin();
                Ok(())
            },
            iters,
        )?;
        let s = summarize(&samples);
        rows.push(vec![
            format!("draft[{dn}]"),
            format!("{:.2}", s.mean),
            format!("{:.2}", s.p50),
            format!("{:.2}", s.p99),
        ]);
        report.push(Json::obj(vec![
            ("exec", Json::str(&format!("draft[{dn}]"))),
            ("mean_ms", Json::num(s.mean)),
            ("p50_ms", Json::num(s.p50)),
        ]));
    }

    // interpreter dot + reduce fast paths: the kernels `--backend
    // interpret` bench lanes lean on once dims grow past the fixture
    // sizes — measured through the full parse->evaluate pipeline like
    // real executables
    if env.runtime.kind() == crate::backend::BackendKind::Interpret {
        use crate::backend::hlo::builder::{HloBuilder, Ty};
        use crate::backend::hlo::eval::{evaluate, Value};
        use crate::backend::hlo::parser::parse_module;
        for &(rows_n, k) in &[(256usize, 512usize), (1024, 256)] {
            let mut hb = HloBuilder::new("redbench");
            let p = hb.param(Ty::F32, vec![rows_n, k]);
            let s = hb.reduce_add(&p, &[1]);
            let mx = hb.reduce_max(&p, &[1]);
            let text = hb.finish(&[&s, &mx]);
            let module = parse_module(&text)?;
            let x = Rc::new(Value::f32(vec![rows_n, k], vec![0.5; rows_n * k]));
            let samples = time_loop(
                || {
                    let _ = evaluate(&module, &[Rc::clone(&x)])?;
                    Ok(())
                },
                iters,
            )?;
            let s = summarize(&samples);
            let name = format!("interp_reduce_{rows_n}x{k}");
            rows.push(vec![
                name.clone(),
                format!("{:.2}", s.mean),
                format!("{:.2}", s.p50),
                format!("{:.2}", s.p99),
            ]);
            report.push(Json::obj(vec![
                ("exec", Json::str(&name)),
                ("mean_ms", Json::num(s.mean)),
                ("p50_ms", Json::num(s.p50)),
            ]));
        }
        for &(m, k, n) in &[(32usize, 64usize, 64usize), (128, 128, 128)] {
            let mut hb = HloBuilder::new("dotbench");
            let pa = hb.param(Ty::F32, vec![m, k]);
            let pb = hb.param(Ty::F32, vec![k, n]);
            let c = hb.matmul(&pa, &pb);
            let text = hb.finish(&[&c]);
            let module = parse_module(&text)?;
            let a = Rc::new(Value::f32(vec![m, k], vec![0.5; m * k]));
            let b = Rc::new(Value::f32(vec![k, n], vec![0.25; k * n]));
            let samples = time_loop(
                || {
                    let _ = evaluate(&module, &[Rc::clone(&a), Rc::clone(&b)])?;
                    Ok(())
                },
                iters,
            )?;
            let s = summarize(&samples);
            let name = format!("interp_dot_{m}x{k}x{n}");
            rows.push(vec![
                name.clone(),
                format!("{:.2}", s.mean),
                format!("{:.2}", s.p50),
                format!("{:.2}", s.p99),
            ]);
            report.push(Json::obj(vec![
                ("exec", Json::str(&name)),
                ("mean_ms", Json::num(s.mean)),
                ("p50_ms", Json::num(s.p50)),
            ]));
        }
    }

    println!("\n=== Microbench (per-call latency, ms) ===");
    let headers: Vec<String> =
        ["op", "mean", "p50", "p99"].iter().map(|s| s.to_string()).collect();
    println!("{}", render_table(&headers, &rows));
    let path = write_report("microbench", &Json::Arr(report))?;
    println!("report -> {path:?}");
    Ok(())
}
