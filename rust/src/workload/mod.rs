//! Workload generation and drivers: held-out task prompts exported by
//! the python side (`artifacts/prompts/<task>.json`), arrival processes,
//! and trace replay through the continuous batcher's `step()` loop for
//! the serving benchmarks.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::{BatchEngine, Request, Response, ServingMetrics};
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// The five synthetic tasks and the paper benchmark each stands in for.
pub const TASKS: [(&str, &str); 5] = [
    ("dialog", "MT-Bench"),
    ("code", "HumanEval"),
    ("math", "GSM8K"),
    ("inst", "Alpaca"),
    ("news", "CNN/DM"),
];

pub fn paper_name(task: &str) -> &'static str {
    TASKS
        .iter()
        .find(|(t, _)| *t == task)
        .map(|(_, p)| *p)
        .unwrap_or("?")
}

/// Load the held-out prompts for one task.
pub fn load_prompts(artifacts_root: &Path, task: &str) -> Result<Vec<String>> {
    let path = artifacts_root.join("prompts").join(format!("{task}.json"));
    let text = std::fs::read_to_string(&path).with_context(|| format!("{path:?}"))?;
    let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let arr = v.as_arr().context("prompt file is not an array")?;
    let out: Vec<String> = arr
        .iter()
        .filter_map(|p| p.as_str().map(String::from))
        .collect();
    if out.is_empty() {
        bail!("{path:?}: no prompts");
    }
    Ok(out)
}

/// One request in an open-loop trace.
#[derive(Debug, Clone)]
pub struct TraceItem {
    /// offset from trace start
    pub at: Duration,
    pub prompt: String,
    pub max_new: usize,
}

/// Poisson arrivals at `rate_per_sec` over `n` requests, prompts drawn
/// uniformly from the pool.
pub fn poisson_trace(
    prompts: &[String],
    n: usize,
    rate_per_sec: f64,
    max_new: usize,
    seed: u64,
) -> Vec<TraceItem> {
    let mut rng = Pcg64::new(seed, 7);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            t += rng.exp() / rate_per_sec.max(1e-9);
            TraceItem {
                at: Duration::from_secs_f64(t),
                prompt: prompts[rng.below(prompts.len())].clone(),
                max_new,
            }
        })
        .collect()
}

/// Bursty trace: `bursts` groups of `burst_size` back-to-back requests
/// separated by `gap`.
pub fn bursty_trace(
    prompts: &[String],
    bursts: usize,
    burst_size: usize,
    gap: Duration,
    max_new: usize,
    seed: u64,
) -> Vec<TraceItem> {
    let mut rng = Pcg64::new(seed, 8);
    let mut out = Vec::with_capacity(bursts * burst_size);
    for b in 0..bursts {
        let at = gap * b as u32;
        for _ in 0..burst_size {
            out.push(TraceItem {
                at,
                prompt: prompts[rng.below(prompts.len())].clone(),
                max_new,
            });
        }
    }
    out
}

/// Pick a serving target for batch > 1 demos/tests: prefer `mid` when
/// its spec lowers a batch size above 1 (smallest such batch wins, so
/// the cheapest batched executables are used), else fall back to
/// `base` at batch 1. Returns the target directory and the batch.
pub fn batched_serving_target(artifacts_root: &Path) -> Option<(std::path::PathBuf, usize)> {
    for target in ["mid", "base"] {
        let dir = artifacts_root.join(target);
        let Ok(text) = std::fs::read_to_string(dir.join("spec.json")) else {
            continue;
        };
        let Ok(spec) = crate::model::ModelSpec::parse(&text) else {
            continue;
        };
        let batch = spec
            .batch_sizes
            .iter()
            .copied()
            .filter(|&b| b > 1)
            .min()
            .unwrap_or(1);
        if batch > 1 || target == "base" {
            return Some((dir, batch));
        }
    }
    None
}

/// Open-loop replay of a trace through the continuous batcher: each item
/// is submitted at its arrival offset and the engine is stepped until
/// every request completes — the same scheduler path the live TCP
/// server drives. Request ids start at `base_id`.
pub fn replay_trace(
    engine: &mut BatchEngine,
    trace: &[TraceItem],
    base_id: u64,
) -> Result<(Vec<Response>, ServingMetrics)> {
    let mut metrics = ServingMetrics::default();
    let mut responses = Vec::new();
    let t0 = Instant::now();
    let mut next = 0usize;
    while next < trace.len() || engine.has_work() {
        while next < trace.len() && trace[next].at <= t0.elapsed() {
            let mut r = Request::new(base_id + next as u64, trace[next].prompt.clone());
            r.cfg.max_new_tokens = trace[next].max_new;
            engine.submit(r);
            next += 1;
        }
        if !engine.has_work() {
            // idle until the next arrival
            let now = t0.elapsed();
            if trace[next].at > now {
                std::thread::sleep(trace[next].at - now);
            }
            continue;
        }
        let done = engine.step(&mut metrics)?;
        if engine.stalled(&done) {
            bail!("trace replay stalled: KV pool cannot cover a single request");
        }
        if let Some(err) = done.iter().find_map(|r| r.error.as_deref()) {
            bail!("request failed during trace replay: {err}");
        }
        responses.extend(done);
    }
    Ok((responses, metrics))
}

/// One request's client-side measurements from an open-loop TCP replay.
/// Latencies are measured from the request's *scheduled* arrival time
/// (not the moment the client thread got around to sending), so a
/// saturated server shows up as tail latency instead of being hidden by
/// coordinated omission.
#[derive(Debug, Clone)]
pub struct TcpReqStat {
    pub index: usize,
    /// scheduled arrival -> first streamed `tokens` frame (TTFT)
    pub ttft_ms: f64,
    /// scheduled arrival -> final response line
    pub total_ms: f64,
    pub tokens: usize,
    /// server-side error reply ("queue full" shed, ...), if any
    pub error: Option<String>,
}

impl TcpReqStat {
    /// Mean decode latency per token after the first frame.
    pub fn per_token_ms(&self) -> f64 {
        (self.total_ms - self.ttft_ms) / (self.tokens.saturating_sub(1).max(1) as f64)
    }
}

/// One request's reply through [`replay_trace_tcp_text`]: the final
/// `text` (empty when the request was answered with an error)
/// alongside the latency stats.
#[derive(Debug, Clone)]
pub struct TcpReqText {
    pub stat: TcpReqStat,
    pub text: String,
}

/// Open-loop replay of a trace against a live TCP server: one client
/// thread per request connects at its arrival offset, sends the
/// request with `"stream": true` (the first `tokens` frame is the TTFT
/// mark), and reads to the final response. This drives the real
/// `coordinator/server.rs` wire path — admission queue, scheduler,
/// streaming flow control — not the in-process engine.
pub fn replay_trace_tcp(addr: &str, trace: &[TraceItem]) -> Result<Vec<TcpReqStat>> {
    Ok(replay_trace_tcp_text(addr, trace)?.into_iter().map(|r| r.stat).collect())
}

/// [`replay_trace_tcp`], also capturing each request's final `text` —
/// the byte-identity hook the multi-replica chaos lane uses to compare
/// routed output (replica killed mid-trace) against a direct run.
pub fn replay_trace_tcp_text(addr: &str, trace: &[TraceItem]) -> Result<Vec<TcpReqText>> {
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (index, item) in trace.iter().cloned().enumerate() {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || -> Result<TcpReqText> {
            let since = t0.elapsed();
            if item.at > since {
                std::thread::sleep(item.at - since);
            }
            let at_ms = item.at.as_secs_f64() * 1e3;
            let elapsed_ms = move || t0.elapsed().as_secs_f64() * 1e3 - at_ms;
            let stream = TcpStream::connect(&addr)?;
            let mut w = stream.try_clone()?;
            let req = Json::obj(vec![
                ("prompt", Json::str(&item.prompt)),
                ("max_new", Json::num(item.max_new as f64)),
                ("stream", Json::Bool(true)),
            ]);
            writeln!(w, "{}", req.to_string())?;
            let mut r = BufReader::new(stream);
            let mut ttft_ms = f64::NAN;
            let mut tokens = 0usize;
            loop {
                let mut line = String::new();
                if r.read_line(&mut line)? == 0 {
                    bail!("connection closed before final response");
                }
                let v = Json::parse(line.trim())
                    .map_err(|e| anyhow::anyhow!("bad reply line: {e}"))?;
                if v.get("event").and_then(Json::as_str) == Some("tokens") {
                    if ttft_ms.is_nan() {
                        ttft_ms = elapsed_ms();
                    }
                    continue;
                }
                let total_ms = elapsed_ms();
                let error = v
                    .get("error")
                    .and_then(Json::as_str)
                    .map(String::from);
                tokens = v
                    .get("new_tokens")
                    .and_then(Json::as_usize)
                    .unwrap_or(0);
                if ttft_ms.is_nan() {
                    ttft_ms = total_ms; // errored before any frame
                }
                let text = v
                    .get("text")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string();
                let stat = TcpReqStat { index, ttft_ms, total_ms, tokens, error };
                return Ok(TcpReqText { stat, text });
            }
        }));
    }
    let mut out = Vec::with_capacity(handles.len());
    for h in handles {
        out.push(h.join().map_err(|_| anyhow::anyhow!("client thread panicked"))??);
    }
    out.sort_by_key(|s| s.stat.index);
    Ok(out)
}

/// One multi-turn chat session: an opening prompt plus follow-up user
/// lines. Turn t's prompt is the accumulated transcript — every earlier
/// prompt and model reply — plus the next user line, so consecutive
/// turns share their entire history as a string prefix. With the byte
/// tokenizer a string prefix is a token prefix, which is exactly the
/// shape that gives the radix prefix cache its hits.
#[derive(Debug, Clone)]
pub struct ChatSession {
    pub opening: String,
    pub followups: Vec<String>,
    pub max_new: usize,
}

/// Build `sessions` chat sessions of `turns` turns each: openings drawn
/// from the prompt pool, follow-ups picked deterministically from a
/// fixed set so the same seed replays the identical trace (the warm-run
/// vs cold-run comparison depends on that).
pub fn chat_sessions(
    prompts: &[String],
    sessions: usize,
    turns: usize,
    max_new: usize,
    seed: u64,
) -> Vec<ChatSession> {
    // deliberately terse: the whole transcript must stay inside the
    // model's prompt budget (`max_seq` minus generation headroom) —
    // over-budget prompts are truncated from the *front*, which
    // destroys the shared prefix the cache would otherwise hit
    const FOLLOWUPS: [&str; 4] = ["And?", "Why?", "Go on.", "More."];
    let mut rng = Pcg64::new(seed, 11);
    (0..sessions)
        .map(|_| ChatSession {
            opening: prompts[rng.below(prompts.len())].clone(),
            followups: (1..turns)
                .map(|_| FOLLOWUPS[rng.below(FOLLOWUPS.len())].to_string())
                .collect(),
            max_new,
        })
        .collect()
}

/// One chat turn's client-side measurements. TTFT is measured from the
/// turn's send, so warm turns (t > 0) directly expose the prefill work
/// the prefix cache skipped.
#[derive(Debug, Clone)]
pub struct ChatTurnStat {
    pub session: usize,
    pub turn: usize,
    /// turn sent -> first streamed `tokens` frame
    pub ttft_ms: f64,
    /// turn sent -> final response line
    pub total_ms: f64,
    pub text: String,
    pub tokens: usize,
}

/// Replay chat sessions against a live TCP server. Sessions run
/// concurrently (one connection each), but turns within a session are
/// strictly sequential: turn t's final response is appended to the
/// transcript before turn t+1 is sent, so by the time the next lookup
/// happens the engine has already published turn t's prefix (requests
/// publish at retirement, before their response is written).
pub fn replay_chat_tcp(addr: &str, sessions: &[ChatSession]) -> Result<Vec<ChatTurnStat>> {
    let mut handles = Vec::new();
    for (s_idx, sess) in sessions.iter().cloned().enumerate() {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || -> Result<Vec<ChatTurnStat>> {
            let stream = TcpStream::connect(&addr)?;
            let mut w = stream.try_clone()?;
            let mut r = BufReader::new(stream);
            let mut context = sess.opening.clone();
            let mut out = Vec::new();
            for turn in 0..sess.followups.len() + 1 {
                if turn > 0 {
                    // the user's next line rides on the full transcript
                    context.push('\n');
                    context.push_str(&sess.followups[turn - 1]);
                }
                let t0 = Instant::now();
                let req = Json::obj(vec![
                    ("prompt", Json::str(&context)),
                    ("max_new", Json::num(sess.max_new as f64)),
                    ("stream", Json::Bool(true)),
                ]);
                writeln!(w, "{}", req.to_string())?;
                let mut ttft_ms = f64::NAN;
                loop {
                    let mut line = String::new();
                    if r.read_line(&mut line)? == 0 {
                        bail!("connection closed mid-session");
                    }
                    let v = Json::parse(line.trim())
                        .map_err(|e| anyhow::anyhow!("bad reply line: {e}"))?;
                    if v.get("event").and_then(Json::as_str) == Some("tokens") {
                        if ttft_ms.is_nan() {
                            ttft_ms = t0.elapsed().as_secs_f64() * 1e3;
                        }
                        continue;
                    }
                    if let Some(err) = v.get("error").and_then(Json::as_str) {
                        bail!("chat turn {turn} of session {s_idx} failed: {err}");
                    }
                    let total_ms = t0.elapsed().as_secs_f64() * 1e3;
                    let text =
                        v.get("text").and_then(Json::as_str).unwrap_or("").to_string();
                    let tokens =
                        v.get("new_tokens").and_then(Json::as_usize).unwrap_or(0);
                    if ttft_ms.is_nan() {
                        ttft_ms = total_ms;
                    }
                    // the reply becomes part of the next turn's context —
                    // the prefix a warm cache serves without prefilling
                    context.push_str(&text);
                    out.push(ChatTurnStat {
                        session: s_idx,
                        turn,
                        ttft_ms,
                        total_ms,
                        text,
                        tokens,
                    });
                    break;
                }
            }
            Ok(out)
        }));
    }
    let mut out = Vec::new();
    for h in handles {
        out.extend(h.join().map_err(|_| anyhow::anyhow!("client thread panicked"))??);
    }
    out.sort_by(|a, b| (a.session, a.turn).cmp(&(b.session, b.turn)));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_interarrivals_have_right_mean() {
        let prompts = vec!["a".to_string(), "b".to_string()];
        let tr = poisson_trace(&prompts, 2000, 10.0, 32, 1);
        assert_eq!(tr.len(), 2000);
        let total = tr.last().unwrap().at.as_secs_f64();
        let rate = 2000.0 / total;
        assert!((rate - 10.0).abs() < 1.0, "rate {rate}");
        assert!(tr.windows(2).all(|w| w[0].at <= w[1].at));
    }

    #[test]
    fn bursty_shape() {
        let prompts = vec!["p".to_string()];
        let tr = bursty_trace(&prompts, 3, 4, Duration::from_secs(1), 16, 2);
        assert_eq!(tr.len(), 12);
        assert_eq!(tr[0].at, tr[3].at);
        assert!(tr[4].at > tr[3].at);
    }

    #[test]
    fn paper_names() {
        assert_eq!(paper_name("code"), "HumanEval");
        assert_eq!(paper_name("nope"), "?");
    }

    #[test]
    fn chat_sessions_are_deterministic() {
        let prompts = vec!["alpha".to_string(), "beta".to_string()];
        let a = chat_sessions(&prompts, 3, 3, 16, 9);
        let b = chat_sessions(&prompts, 3, 3, 16, 9);
        assert_eq!(a.len(), 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.opening, y.opening);
            assert_eq!(x.followups, y.followups);
            assert_eq!(x.followups.len(), 2, "3 turns = opening + 2 follow-ups");
            assert_eq!(x.max_new, 16);
        }
    }

    #[test]
    fn per_token_latency_excludes_ttft() {
        let s = TcpReqStat {
            index: 0,
            ttft_ms: 10.0,
            total_ms: 110.0,
            tokens: 11,
            error: None,
        };
        assert!((s.per_token_ms() - 10.0).abs() < 1e-9);
        // degenerate outputs never divide by zero
        let s = TcpReqStat { tokens: 0, ..s };
        assert!((s.per_token_ms() - 100.0).abs() < 1e-9);
    }
}
