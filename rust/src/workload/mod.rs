//! Workload generation and drivers: held-out task prompts exported by
//! the python side (`artifacts/prompts/<task>.json`), arrival processes,
//! and trace replay through the continuous batcher's `step()` loop for
//! the serving benchmarks.

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::{BatchEngine, Request, Response, ServingMetrics};
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// The five synthetic tasks and the paper benchmark each stands in for.
pub const TASKS: [(&str, &str); 5] = [
    ("dialog", "MT-Bench"),
    ("code", "HumanEval"),
    ("math", "GSM8K"),
    ("inst", "Alpaca"),
    ("news", "CNN/DM"),
];

pub fn paper_name(task: &str) -> &'static str {
    TASKS
        .iter()
        .find(|(t, _)| *t == task)
        .map(|(_, p)| *p)
        .unwrap_or("?")
}

/// Load the held-out prompts for one task.
pub fn load_prompts(artifacts_root: &Path, task: &str) -> Result<Vec<String>> {
    let path = artifacts_root.join("prompts").join(format!("{task}.json"));
    let text = std::fs::read_to_string(&path).with_context(|| format!("{path:?}"))?;
    let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let arr = v.as_arr().context("prompt file is not an array")?;
    let out: Vec<String> = arr
        .iter()
        .filter_map(|p| p.as_str().map(String::from))
        .collect();
    if out.is_empty() {
        bail!("{path:?}: no prompts");
    }
    Ok(out)
}

/// One request in an open-loop trace.
#[derive(Debug, Clone)]
pub struct TraceItem {
    /// offset from trace start
    pub at: Duration,
    pub prompt: String,
    pub max_new: usize,
}

/// Poisson arrivals at `rate_per_sec` over `n` requests, prompts drawn
/// uniformly from the pool.
pub fn poisson_trace(
    prompts: &[String],
    n: usize,
    rate_per_sec: f64,
    max_new: usize,
    seed: u64,
) -> Vec<TraceItem> {
    let mut rng = Pcg64::new(seed, 7);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            t += rng.exp() / rate_per_sec.max(1e-9);
            TraceItem {
                at: Duration::from_secs_f64(t),
                prompt: prompts[rng.below(prompts.len())].clone(),
                max_new,
            }
        })
        .collect()
}

/// Bursty trace: `bursts` groups of `burst_size` back-to-back requests
/// separated by `gap`.
pub fn bursty_trace(
    prompts: &[String],
    bursts: usize,
    burst_size: usize,
    gap: Duration,
    max_new: usize,
    seed: u64,
) -> Vec<TraceItem> {
    let mut rng = Pcg64::new(seed, 8);
    let mut out = Vec::with_capacity(bursts * burst_size);
    for b in 0..bursts {
        let at = gap * b as u32;
        for _ in 0..burst_size {
            out.push(TraceItem {
                at,
                prompt: prompts[rng.below(prompts.len())].clone(),
                max_new,
            });
        }
    }
    out
}

/// Pick a serving target for batch > 1 demos/tests: prefer `mid` when
/// its spec lowers a batch size above 1 (smallest such batch wins, so
/// the cheapest batched executables are used), else fall back to
/// `base` at batch 1. Returns the target directory and the batch.
pub fn batched_serving_target(artifacts_root: &Path) -> Option<(std::path::PathBuf, usize)> {
    for target in ["mid", "base"] {
        let dir = artifacts_root.join(target);
        let Ok(text) = std::fs::read_to_string(dir.join("spec.json")) else {
            continue;
        };
        let Ok(spec) = crate::model::ModelSpec::parse(&text) else {
            continue;
        };
        let batch = spec
            .batch_sizes
            .iter()
            .copied()
            .filter(|&b| b > 1)
            .min()
            .unwrap_or(1);
        if batch > 1 || target == "base" {
            return Some((dir, batch));
        }
    }
    None
}

/// Open-loop replay of a trace through the continuous batcher: each item
/// is submitted at its arrival offset and the engine is stepped until
/// every request completes — the same scheduler path the live TCP
/// server drives. Request ids start at `base_id`.
pub fn replay_trace(
    engine: &mut BatchEngine,
    trace: &[TraceItem],
    base_id: u64,
) -> Result<(Vec<Response>, ServingMetrics)> {
    let mut metrics = ServingMetrics::default();
    let mut responses = Vec::new();
    let t0 = Instant::now();
    let mut next = 0usize;
    while next < trace.len() || engine.has_work() {
        while next < trace.len() && trace[next].at <= t0.elapsed() {
            let mut r = Request::new(base_id + next as u64, trace[next].prompt.clone());
            r.cfg.max_new_tokens = trace[next].max_new;
            engine.submit(r);
            next += 1;
        }
        if !engine.has_work() {
            // idle until the next arrival
            let now = t0.elapsed();
            if trace[next].at > now {
                std::thread::sleep(trace[next].at - now);
            }
            continue;
        }
        let done = engine.step(&mut metrics)?;
        if engine.stalled(&done) {
            bail!("trace replay stalled: KV pool cannot cover a single request");
        }
        if let Some(err) = done.iter().find_map(|r| r.error.as_deref()) {
            bail!("request failed during trace replay: {err}");
        }
        responses.extend(done);
    }
    Ok((responses, metrics))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_interarrivals_have_right_mean() {
        let prompts = vec!["a".to_string(), "b".to_string()];
        let tr = poisson_trace(&prompts, 2000, 10.0, 32, 1);
        assert_eq!(tr.len(), 2000);
        let total = tr.last().unwrap().at.as_secs_f64();
        let rate = 2000.0 / total;
        assert!((rate - 10.0).abs() < 1.0, "rate {rate}");
        assert!(tr.windows(2).all(|w| w[0].at <= w[1].at));
    }

    #[test]
    fn bursty_shape() {
        let prompts = vec!["p".to_string()];
        let tr = bursty_trace(&prompts, 3, 4, Duration::from_secs(1), 16, 2);
        assert_eq!(tr.len(), 12);
        assert_eq!(tr[0].at, tr[3].at);
        assert!(tr[4].at > tr[3].at);
    }

    #[test]
    fn paper_names() {
        assert_eq!(paper_name("code"), "HumanEval");
        assert_eq!(paper_name("nope"), "?");
    }
}
