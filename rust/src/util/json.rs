//! Minimal JSON parser/writer substrate.
//!
//! The offline crate registry in this environment does not ship `serde`
//! (see DESIGN.md §Substitutions), so the executable manifests
//! (`*.io.json`), model specs (`spec.json`), prompt files and the TCP API
//! all go through this hand-rolled implementation. It supports the full
//! JSON grammar minus exotic number forms; numbers are kept as f64 with
//! integer accessors.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")`
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // ---- writer ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                if self.b.len() < self.pos + 7
                                    || self.b[self.pos + 1] != b'\\'
                                    || self.b[self.pos + 2] != b'u'
                                {
                                    return Err(self.err("lone surrogate"));
                                }
                                let hex2 = std::str::from_utf8(
                                    &self.b[self.pos + 3..self.pos + 7],
                                )
                                .map_err(|_| self.err("bad surrogate"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad surrogate"))?;
                                self.pos += 6;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(
                                    char::from_u32(c).ok_or_else(|| self.err("bad cp"))?,
                                );
                            } else {
                                out.push(
                                    char::from_u32(cp).ok_or_else(|| self.err("bad cp"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 sequence
                    let start = self.pos;
                    let len = utf8_len(self.b[self.pos]);
                    self.pos += len;
                    if self.pos > self.b.len() {
                        return Err(self.err("bad utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xE0 {
        2
    } else if b < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":false}"#).unwrap();
        assert_eq!(v.path("c"), Some(&Json::Bool(false)));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].path("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        let v = Json::parse("\"caf\u{00e9}\"").unwrap();
        assert_eq!(v.as_str(), Some("café"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"s":"line\nbreak","t":true},"z":null}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn integer_writer_is_exact() {
        let v = Json::Num(272.0);
        assert_eq!(v.to_string(), "272");
    }
}
