//! Phase-timing substrate: accumulates wall-clock per named phase so the
//! engine can report the draft/verify/accept/update latency breakdown
//! (EXPERIMENTS.md §Perf uses these numbers directly).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    acc: BTreeMap<&'static str, (Duration, u64)>,
}

pub struct Running<'a> {
    timer: &'a mut PhaseTimer,
    phase: &'static str,
    start: Instant,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn start(&mut self, phase: &'static str) -> Running<'_> {
        Running { start: Instant::now(), phase, timer: self }
    }

    pub fn record(&mut self, phase: &'static str, d: Duration) {
        let e = self.acc.entry(phase).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    pub fn total(&self, phase: &str) -> Duration {
        self.acc.get(phase).map(|e| e.0).unwrap_or(Duration::ZERO)
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.acc.get(phase).map(|e| e.1).unwrap_or(0)
    }

    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, (d, c)) in &other.acc {
            let e = self.acc.entry(k).or_insert((Duration::ZERO, 0));
            e.0 += *d;
            e.1 += *c;
        }
    }

    pub fn phases(&self) -> impl Iterator<Item = (&'static str, Duration, u64)> + '_ {
        self.acc.iter().map(|(k, (d, c))| (*k, *d, *c))
    }

    pub fn report(&self) -> String {
        let mut lines = Vec::new();
        let total: Duration = self.acc.values().map(|e| e.0).sum();
        for (k, (d, c)) in &self.acc {
            let pct = if total.as_nanos() > 0 {
                100.0 * d.as_secs_f64() / total.as_secs_f64()
            } else {
                0.0
            };
            lines.push(format!(
                "  {k:<16} {:>9.1}ms  {c:>7} calls  {pct:>5.1}%",
                d.as_secs_f64() * 1e3
            ));
        }
        lines.join("\n")
    }
}

impl Drop for Running<'_> {
    fn drop(&mut self) {
        let d = self.start.elapsed();
        self.timer.record(self.phase, d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut t = PhaseTimer::new();
        t.record("draft", Duration::from_millis(5));
        t.record("draft", Duration::from_millis(7));
        t.record("verify", Duration::from_millis(1));
        assert_eq!(t.count("draft"), 2);
        assert_eq!(t.total("draft"), Duration::from_millis(12));
        assert_eq!(t.count("missing"), 0);
    }

    #[test]
    fn raii_guard_records() {
        let mut t = PhaseTimer::new();
        {
            let _g = t.start("x");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(t.count("x"), 1);
        assert!(t.total("x") >= Duration::from_millis(1));
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseTimer::new();
        let mut b = PhaseTimer::new();
        a.record("p", Duration::from_millis(1));
        b.record("p", Duration::from_millis(2));
        a.merge(&b);
        assert_eq!(a.total("p"), Duration::from_millis(3));
        assert_eq!(a.count("p"), 2);
    }
}
