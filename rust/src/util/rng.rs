//! Deterministic RNG + sampling substrate (no `rand` crate offline).
//!
//! PCG64 (O'Neill) for the stream, plus the sampling primitives the
//! speculative-decoding engine needs: uniform, categorical, top-k /
//! top-p filtering, and Gumbel-free multinomial draws from normalized
//! probability vectors. Deterministic across runs for reproducible
//! experiments (EXPERIMENTS.md records the seeds).

#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    fn step(&mut self) {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
    }

    /// XSL-RR output function.
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Exponential(1) variate (for Poisson arrival processes).
    pub fn exp(&mut self) -> f64 {
        let u = self.next_f64().max(1e-300);
        -u.ln()
    }

    /// Draw an index from a normalized probability vector.
    /// Falls back to argmax if the vector doesn't sum to ~1.
    pub fn categorical(&mut self, probs: &[f32]) -> usize {
        let r = self.next_f64() as f32;
        let mut acc = 0.0f32;
        for (i, &p) in probs.iter().enumerate() {
            acc += p;
            if r < acc {
                return i;
            }
        }
        // numerical tail: return the last index with non-zero mass
        probs
            .iter()
            .rposition(|&p| p > 0.0)
            .unwrap_or(probs.len() - 1)
    }
}

/// Indices of the k largest values (descending by value). O(V·k) — V is
/// tiny (272) so this beats heap overhead on the hot path.
pub fn top_k_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(xs.len());
    let mut idx: Vec<usize> = Vec::with_capacity(k);
    for _ in 0..k {
        let mut best = usize::MAX;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &v) in xs.iter().enumerate() {
            if idx.contains(&i) {
                continue;
            }
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        if best == usize::MAX {
            break;
        }
        idx.push(best);
    }
    idx
}

/// In-place softmax with temperature; temperature == 0 produces a
/// one-hot at the argmax (greedy limit).
pub fn softmax_temp(logits: &mut [f32], temperature: f32) {
    if logits.is_empty() {
        return;
    }
    if temperature <= 0.0 {
        let arg = argmax(logits);
        for v in logits.iter_mut() {
            *v = 0.0;
        }
        logits[arg] = 1.0;
        return;
    }
    let inv = 1.0 / temperature;
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in logits.iter_mut() {
        *v = ((*v - m) * inv).exp();
        sum += *v;
    }
    let inv_sum = 1.0 / sum;
    for v in logits.iter_mut() {
        *v *= inv_sum;
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new(42, 2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Pcg64::new(7, 0);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg64::new(3, 0);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn categorical_matches_distribution() {
        let mut r = Pcg64::new(11, 0);
        let probs = [0.1f32, 0.2, 0.7];
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&probs)] += 1;
        }
        assert!((counts[2] as f64 / 30_000.0 - 0.7).abs() < 0.02, "{counts:?}");
        assert!((counts[0] as f64 / 30_000.0 - 0.1).abs() < 0.02, "{counts:?}");
    }

    #[test]
    fn top_k_sorted_desc() {
        let xs = [0.1f32, 5.0, -2.0, 3.0, 3.5];
        assert_eq!(top_k_indices(&xs, 3), vec![1, 4, 3]);
        assert_eq!(top_k_indices(&xs, 99).len(), 5);
    }

    #[test]
    fn softmax_temp_greedy_limit() {
        let mut l = vec![1.0f32, 3.0, 2.0];
        softmax_temp(&mut l, 0.0);
        assert_eq!(l, vec![0.0, 1.0, 0.0]);
        let mut l2 = vec![1.0f32, 3.0, 2.0];
        softmax_temp(&mut l2, 1.0);
        let s: f32 = l2.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(l2[1] > l2[2] && l2[2] > l2[0]);
    }

    #[test]
    fn exp_mean_is_one() {
        let mut r = Pcg64::new(5, 0);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp()).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.05, "{mean}");
    }
}
