//! Leveled stderr logger substrate, controlled by `FE_LOG`
//! (error|warn|info|debug|trace; default info).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn init_level() -> u8 {
    let lvl = match std::env::var("FE_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == u8::MAX {
        cur = init_level();
    }
    (level as u8) <= cur
}

pub fn start_time() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

pub fn log(level: Level, module: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = start_time().elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:>9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_level_allows_info() {
        // FE_LOG unset in tests -> info enabled, debug not necessarily
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
    }
}
