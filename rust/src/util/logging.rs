//! Leveled stderr logger substrate, controlled by `FE_LOG`.
//!
//! The spec is a comma-separated list of directives:
//! * a bare level (`error|warn|info|debug|trace`) sets the default;
//! * `module=level` raises/lowers one module subtree, where `module`
//!   matches whole `::`-separated path segments of `module_path!()`
//!   (so `backend=trace` covers `fasteagle::backend::interp`, and the
//!   most specific — longest — matching rule wins).
//!
//! `FE_LOG=info,backend=trace` keeps the default at info but traces the
//! backend. Unrecognized directives (`FE_LOG=vebose`) are reported once
//! on stderr instead of being silently swallowed. Default: `info`.

use std::io::Write;
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

fn parse_level(s: &str) -> Option<Level> {
    match s {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

/// Compiled `FE_LOG` spec.
#[derive(Debug, Clone)]
pub struct Filters {
    default: Level,
    /// (module pattern, level); most specific match wins
    rules: Vec<(String, Level)>,
    /// highest level any rule (or the default) can enable — the global
    /// fast-path bound
    max: Level,
}

/// Parse an `FE_LOG` spec. Pure: returns the filters plus any
/// unrecognized directives for the caller to report.
pub fn parse_spec(spec: &str) -> (Filters, Vec<String>) {
    let mut default = Level::Info;
    let mut rules: Vec<(String, Level)> = Vec::new();
    let mut unknown = Vec::new();
    for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        if let Some((module, lvl)) = tok.split_once('=') {
            match parse_level(lvl.trim()) {
                Some(l) => rules.push((module.trim().to_string(), l)),
                None => unknown.push(tok.to_string()),
            }
        } else {
            match parse_level(tok) {
                Some(l) => default = l,
                None => unknown.push(tok.to_string()),
            }
        }
    }
    let max = rules.iter().map(|(_, l)| *l).fold(default, Level::max);
    (Filters { default, rules, max }, unknown)
}

/// Does `rule` match `module` on whole `::`-segment boundaries?
fn module_matches(module: &str, rule: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = module[start..].find(rule) {
        let b = start + pos;
        let e = b + rule.len();
        let left_ok = b == 0 || module[..b].ends_with("::");
        let right_ok = e == module.len() || module[e..].starts_with("::");
        if left_ok && right_ok {
            return true;
        }
        start = b + 1;
    }
    false
}

impl Filters {
    /// Effective level for one `module_path!()` string.
    pub fn level_for(&self, module: &str) -> Level {
        let mut best: Option<(usize, Level)> = None;
        for (m, l) in &self.rules {
            if module_matches(module, m) && best.is_none_or(|(len, _)| m.len() >= len) {
                best = Some((m.len(), *l));
            }
        }
        best.map(|(_, l)| l).unwrap_or(self.default)
    }
}

fn filters() -> &'static Filters {
    static F: OnceLock<Filters> = OnceLock::new();
    F.get_or_init(|| {
        let spec = std::env::var("FE_LOG").unwrap_or_default();
        let (f, unknown) = parse_spec(&spec);
        for tok in &unknown {
            let mut err = std::io::stderr().lock();
            let _ = writeln!(
                err,
                "[FE_LOG] unrecognized directive {tok:?} \
                 (expected error|warn|info|debug|trace or module=level); ignored"
            );
        }
        f
    })
}

/// Global fast path: could any module emit at this level?
pub fn enabled(level: Level) -> bool {
    level <= filters().max
}

/// Is `level` enabled for this specific module?
pub fn enabled_for(level: Level, module: &str) -> bool {
    level <= filters().level_for(module)
}

pub fn start_time() -> Instant {
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

pub fn log(level: Level, module: &str, msg: &str) {
    if !enabled(level) || !enabled_for(level, module) {
        return;
    }
    let t = start_time().elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:>9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_level_allows_info() {
        // FE_LOG unset in tests -> info enabled, debug not necessarily
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
    }

    #[test]
    fn parse_bare_levels_including_explicit_info() {
        for (spec, want) in [
            ("error", Level::Error),
            ("warn", Level::Warn),
            ("info", Level::Info),
            ("debug", Level::Debug),
            ("trace", Level::Trace),
        ] {
            let (f, unknown) = parse_spec(spec);
            assert!(unknown.is_empty(), "{spec}: {unknown:?}");
            assert_eq!(f.level_for("fasteagle::spec"), want, "{spec}");
        }
    }

    #[test]
    fn unrecognized_directives_are_reported_not_swallowed() {
        let (f, unknown) = parse_spec("vebose");
        assert_eq!(unknown, vec!["vebose".to_string()]);
        // falls back to the default rather than silently disabling
        assert_eq!(f.level_for("fasteagle::spec"), Level::Info);
        let (_, unknown) = parse_spec("debug,backend=vebose");
        assert_eq!(unknown, vec!["backend=vebose".to_string()]);
    }

    #[test]
    fn per_module_rules_match_path_segments() {
        let (f, unknown) = parse_spec("info,backend=trace");
        assert!(unknown.is_empty());
        assert_eq!(f.level_for("fasteagle::backend::interp"), Level::Trace);
        assert_eq!(f.level_for("fasteagle::backend"), Level::Trace);
        assert_eq!(f.level_for("fasteagle::spec::engine"), Level::Info);
        assert!(f.level_for("fasteagle::coordinator") == Level::Info);
    }

    #[test]
    fn rules_respect_segment_boundaries() {
        let (f, _) = parse_spec("warn,end=trace");
        // "end" must not match inside "backend"
        assert_eq!(f.level_for("fasteagle::backend::interp"), Level::Warn);
        assert_eq!(f.level_for("fasteagle::end"), Level::Trace);
    }

    #[test]
    fn most_specific_rule_wins() {
        let (f, _) = parse_spec("backend=debug,backend::interp=trace");
        assert_eq!(f.level_for("fasteagle::backend::interp"), Level::Trace);
        assert_eq!(f.level_for("fasteagle::backend::fixture"), Level::Debug);
    }

    #[test]
    fn rules_can_lower_below_the_default() {
        let (f, _) = parse_spec("debug,runtime=error");
        assert_eq!(f.level_for("fasteagle::runtime::client"), Level::Error);
        assert_eq!(f.level_for("fasteagle::spec"), Level::Debug);
        // the global fast path still reflects the loudest series
        assert_eq!(f.max, Level::Debug);
    }

    #[test]
    fn empty_and_whitespace_specs_are_default_info() {
        for spec in ["", " ", ",", " , "] {
            let (f, unknown) = parse_spec(spec);
            assert!(unknown.is_empty(), "{spec:?}");
            assert_eq!(f.level_for("fasteagle::spec"), Level::Info, "{spec:?}");
        }
    }
}
