//! Substrate modules built from scratch (the offline crate registry has
//! no serde/clap/rand/criterion/tokio — see DESIGN.md §Substitutions).

pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timer;
