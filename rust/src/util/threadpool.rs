//! Fixed-size thread pool substrate (tokio is unavailable offline; the
//! coordinator's server and workload drivers use OS threads + channels).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize, name: &str) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let handle = thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // channel closed: shut down
                    }
                })
                .expect("spawn worker");
            workers.push(handle);
        }
        ThreadPool { sender: Some(tx), workers }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Run `f` over all items, collecting results in order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rx.recv().expect("worker result");
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4, "t");
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3, "t");
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }
}
