//! Tiny CLI argument parser substrate (`clap` is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments; typed accessors with defaults; and usage generation.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

pub const FLAG_SET: &str = "true";

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag
                    let is_val = it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if is_val {
                        out.flags.insert(rest.to_string(), it.next().unwrap());
                    } else {
                        out.flags.insert(rest.to_string(), FLAG_SET.to_string());
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list value.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').filter(|s| !s.is_empty()).map(String::from).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = p(&["serve", "--port", "8080", "--quick", "--mode=tree", "extra"]);
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.usize_or("port", 0), 8080);
        assert!(a.bool_flag("quick"));
        assert_eq!(a.str_or("mode", ""), "tree");
    }

    #[test]
    fn defaults() {
        let a = p(&[]);
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.f64_or("missing", 0.5), 0.5);
        assert!(!a.bool_flag("missing"));
        assert_eq!(a.list_or("ts", &["x", "y"]), vec!["x", "y"]);
    }

    #[test]
    fn lists() {
        let a = p(&["--targets", "base,large"]);
        assert_eq!(a.list_or("targets", &[]), vec!["base", "large"]);
    }

    #[test]
    fn flag_before_flag() {
        let a = p(&["--quick", "--port", "1"]);
        assert!(a.bool_flag("quick"));
        assert_eq!(a.usize_or("port", 0), 1);
    }
}
