//! Statistics substrate for the bench harnesses (criterion is not
//! available offline): summary stats, percentiles, and a latency
//! histogram with logarithmic buckets for the serving metrics.

/// Summary of a sample of measurements.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 0.50),
        p90: percentile_sorted(&sorted, 0.90),
        p99: percentile_sorted(&sorted, 0.99),
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Log-bucketed latency histogram (microsecond resolution, ~4% buckets).
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum_us: f64,
    max_us: f64,
}

const HIST_BUCKETS: usize = 400;
const HIST_GROWTH: f64 = 1.04;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { buckets: vec![0; HIST_BUCKETS], count: 0, sum_us: 0.0, max_us: 0.0 }
    }

    fn bucket_of(us: f64) -> usize {
        if us <= 1.0 {
            return 0;
        }
        let b = us.ln() / HIST_GROWTH.ln();
        (b as usize).min(HIST_BUCKETS - 1)
    }

    /// Representative value for bucket `i`, which covers
    /// `[growth^i, growth^(i+1))`: the geometric midpoint of the bucket
    /// bounds. The lower edge would systematically underestimate every
    /// percentile by up to one ~4% bucket.
    fn bucket_value(i: usize) -> f64 {
        HIST_GROWTH.powf(i as f64 + 0.5)
    }

    pub fn record_us(&mut self, us: f64) {
        self.buckets[Self::bucket_of(us)] += 1;
        self.count += 1;
        self.sum_us += us;
        if us > self.max_us {
            self.max_us = us;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64
        }
    }

    pub fn percentile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                // the midpoint can overshoot the largest recorded value
                // when the top sample sits low in its bucket
                return Self::bucket_value(i).min(self.max_us);
            }
        }
        self.max_us
    }

    pub fn sum_us(&self) -> f64 {
        self.sum_us
    }

    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    /// Samples in buckets whose upper edge is ≤ `bound_us` — the
    /// conservative cumulative count a Prometheus `le` bucket needs
    /// (never counts a sample above the bound; monotonic in the bound).
    /// The last bucket is open-ended and never counted.
    pub fn count_le_us(&self, bound_us: f64) -> u64 {
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate().take(HIST_BUCKETS - 1) {
            if HIST_GROWTH.powi(i as i32 + 1) > bound_us {
                break;
            }
            acc += c;
        }
        acc
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_safe() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(percentile_sorted(&[], 0.9), 0.0);
    }

    #[test]
    fn histogram_percentiles_are_close() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record_us(i as f64);
        }
        let p50 = h.percentile_us(0.5);
        assert!((p50 - 500.0).abs() / 500.0 < 0.03, "{p50}");
        let p99 = h.percentile_us(0.99);
        assert!((p99 - 990.0).abs() / 990.0 < 0.03, "{p99}");
        assert!((h.mean_us() - 500.5).abs() < 1.0);
    }

    #[test]
    fn histogram_percentile_never_exceeds_max() {
        let mut h = Histogram::new();
        h.record_us(100.0);
        assert!(h.percentile_us(1.0) <= 100.0);
        assert!(h.percentile_us(0.5) > 95.0);
        assert_eq!(h.max_us(), 100.0);
        assert_eq!(h.sum_us(), 100.0);
    }

    #[test]
    fn histogram_cumulative_le_counts() {
        let mut h = Histogram::new();
        for us in [5.0, 50.0, 500.0, 5000.0] {
            h.record_us(us);
        }
        assert_eq!(h.count_le_us(10.0), 1);
        assert_eq!(h.count_le_us(100.0), 2);
        assert_eq!(h.count_le_us(1e3), 3);
        assert_eq!(h.count_le_us(1e4), 4);
        // never counts a sample above the bound
        assert_eq!(h.count_le_us(4.0), 0);
        // monotone in the bound
        let mut last = 0;
        for b in [10.0, 100.0, 1e3, 1e4, 1e5, 1e6] {
            let c = h.count_le_us(b);
            assert!(c >= last);
            last = c;
        }
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record_us(10.0);
        b.record_us(1000.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.percentile_us(1.0) >= 900.0);
    }
}
