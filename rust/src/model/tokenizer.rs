//! Byte-level tokenizer, mirroring `python/compile/data.py`:
//! ids 0..255 are raw bytes, then BOS/EOS/PAD specials.

#[derive(Debug, Clone, Copy)]
pub struct Tokenizer {
    pub bos: i32,
    pub eos: i32,
    pub pad: i32,
}

impl Tokenizer {
    pub fn new(bos: i32, eos: i32, pad: i32) -> Tokenizer {
        Tokenizer { bos, eos, pad }
    }

    /// Encode text (no specials added).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    /// Encode with a leading BOS (the prompt form the models saw in
    /// training).
    pub fn encode_prompt(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::with_capacity(text.len() + 1);
        out.push(self.bos);
        out.extend(text.bytes().map(|b| b as i32));
        out
    }

    /// Decode, dropping special/out-of-range ids and invalid utf-8.
    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn is_special(&self, t: i32) -> bool {
        t == self.bos || t == self.eos || t == self.pad
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::new(256, 257, 258)
    }

    #[test]
    fn roundtrip_ascii() {
        let t = tok();
        let ids = t.encode("hello, world");
        assert_eq!(t.decode(&ids), "hello, world");
    }

    #[test]
    fn prompt_has_bos() {
        let t = tok();
        let ids = t.encode_prompt("ab");
        assert_eq!(ids, vec![256, 97, 98]);
    }

    #[test]
    fn decode_skips_specials() {
        let t = tok();
        assert_eq!(t.decode(&[256, 104, 105, 257, 258]), "hi");
    }

    #[test]
    fn utf8_multibyte_roundtrip() {
        let t = tok();
        let s = "café→☂";
        assert_eq!(t.decode(&t.encode(s)), s);
    }
}
