//! Paged KV accounting: a block allocator in the vLLM mold, extended
//! with refcounted copy-on-write sharing for the prefix cache.
//!
//! The PJRT executables use dense per-request KV tensors (fixed shapes),
//! so the paged layer manages *capacity*, not addresses: admission
//! control and preemption in the continuous-batching coordinator are
//! driven by block availability. This is what produces the paper's
//! Table-3 memory-pressure effect — FastEagle's cascade keeps N drafter
//! KV layers alive per request vs EAGLE's 1, so its per-request block
//! cost is higher and throughput saturates at smaller batch sizes.
//!
//! **Sharing model** (`crate::cache`): a block normally has one holder
//! (the lease it was allocated into). [`BlockPool::retain`] adds a
//! reference — the same block id now funds two holders but occupies one
//! block of capacity, which is exactly the prefix cache's saving.
//! Shared blocks are read-only by contract; a writer that must append
//! into a shared tail block first calls [`BlockPool::fork_tail`]
//! (copy-on-write: the share is replaced by a private block, the cached
//! copy stays intact for other readers). A block returns to the free
//! list only when its last reference is released.
//!
//! **Leak guard**: in debug builds a [`Lease`] dropped with live blocks
//! panics — capacity silently stranded is a bug, not a condition to
//! limp through. [`BlockPool::leaked_blocks`] reports blocks issued but
//! never returned; engines assert it is zero at shutdown.

use std::collections::HashMap;

use anyhow::{bail, Result};

#[derive(Debug)]
pub struct BlockPool {
    block_slots: usize,
    /// recycled block ids (released leases)
    free: Vec<u32>,
    /// first never-issued id: ids `next..total` exist only as capacity,
    /// so an effectively-unbounded pool (the engine's default is
    /// `usize::MAX / 4` blocks) costs nothing until leased
    next: usize,
    total: usize,
    /// refcounts for *shared* blocks only (count >= 2). A live block
    /// with no entry has exactly one holder; a freed block has none.
    refs: HashMap<u32, u32>,
}

/// Blocks leased to one request; freed by returning to the pool.
/// Dropping a lease that still holds blocks is a leak — debug builds
/// panic so the accounting bug is found where it happens.
#[derive(Debug, Default)]
pub struct Lease {
    pub blocks: Vec<u32>,
}

impl Drop for Lease {
    fn drop(&mut self) {
        if cfg!(debug_assertions) && !self.blocks.is_empty() && !std::thread::panicking() {
            panic!(
                "Lease dropped with {} live blocks — release it to the pool first",
                self.blocks.len()
            );
        }
    }
}

impl BlockPool {
    pub fn new(total_blocks: usize, block_slots: usize) -> BlockPool {
        assert!(block_slots > 0);
        BlockPool {
            block_slots,
            free: Vec::new(),
            next: 0,
            total: total_blocks,
            refs: HashMap::new(),
        }
    }

    pub fn block_slots(&self) -> usize {
        self.block_slots
    }

    pub fn available(&self) -> usize {
        self.free.len() + (self.total - self.next)
    }

    pub fn total(&self) -> usize {
        self.total
    }

    /// Blocks issued and not yet fully returned — live leases plus
    /// cache-held shares. Nonzero after every lease and cache reference
    /// has been released means capacity was stranded; engines assert
    /// zero at shutdown.
    pub fn leaked_blocks(&self) -> usize {
        self.next - self.free.len()
    }

    /// References on a block: 0 = free/never issued tracking aside,
    /// 1 = single holder, >=2 = shared. (A never-issued or freed block
    /// reports 1 too — callers only consult this for blocks they hold.)
    pub fn refcount(&self, block: u32) -> u32 {
        self.refs.get(&block).copied().unwrap_or(1)
    }

    /// Is this block held by more than one owner (lease or cache)?
    pub fn is_shared(&self, block: u32) -> bool {
        self.refs.contains_key(&block)
    }

    /// Blocks needed to hold `slots` KV rows across `kv_layers` layers
    /// (each layer stores K and V).
    pub fn blocks_for(&self, slots: usize, kv_layers: usize) -> usize {
        let per_layer = slots.div_ceil(self.block_slots);
        per_layer * kv_layers * 2
    }

    pub fn can_alloc(&self, n: usize) -> bool {
        self.available() >= n
    }

    pub fn alloc(&mut self, n: usize, lease: &mut Lease) -> Result<()> {
        if self.available() < n {
            bail!("block pool exhausted: want {n}, have {}", self.available());
        }
        for _ in 0..n {
            match self.free.pop() {
                Some(b) => lease.blocks.push(b),
                None => {
                    // ids are capacity accounting, not addresses — a
                    // wrap past u32 would need >4e9 live blocks
                    lease.blocks.push(self.next as u32);
                    self.next += 1;
                }
            }
        }
        Ok(())
    }

    /// Grow a lease to cover `slots` slots (allocating only the delta).
    pub fn ensure(
        &mut self,
        lease: &mut Lease,
        slots: usize,
        kv_layers: usize,
    ) -> Result<()> {
        let want = self.blocks_for(slots, kv_layers);
        if lease.blocks.len() < want {
            let delta = want - lease.blocks.len();
            self.alloc(delta, lease)?;
        }
        Ok(())
    }

    /// Add one reference to each of `blocks` (prefix-cache adoption:
    /// the same physical capacity now funds another holder). The caller
    /// must hold a reference to every block it retains.
    pub fn retain(&mut self, blocks: &[u32]) {
        for &b in blocks {
            *self.refs.entry(b).or_insert(1) += 1;
        }
    }

    /// Drop one reference on `block`; returns true when that was the
    /// last reference and the block went back to the free list.
    fn release_one(&mut self, block: u32) -> bool {
        match self.refs.get_mut(&block) {
            Some(c) if *c > 2 => {
                *c -= 1;
                false
            }
            Some(_) => {
                // down to a single holder: back to implicit refcount 1
                self.refs.remove(&block);
                false
            }
            None => {
                self.free.push(block);
                debug_assert!(self.free.len() <= self.total);
                true
            }
        }
    }

    /// Drop one reference on each of `blocks` (cache eviction path);
    /// returns how many blocks actually became free.
    pub fn release_blocks(&mut self, blocks: &[u32]) -> usize {
        blocks.iter().filter(|&&b| self.release_one(b)).count()
    }

    pub fn release(&mut self, lease: &mut Lease) {
        for b in std::mem::take(&mut lease.blocks) {
            self.release_one(b);
        }
    }

    /// Copy-on-write fork: if the lease's tail block is shared, replace
    /// it with a freshly allocated private block and drop the share (the
    /// cached copy stays intact for other readers). No-op on an empty
    /// lease or a private tail. Returns true when a fork happened.
    ///
    /// The serving path publishes and adopts whole `block_slots` runs,
    /// so its shared blocks are always full and never appended into —
    /// this guard fires only for sub-block sharing (exercised by the
    /// pool property tests), keeping the read-only contract on shared
    /// blocks unconditional.
    pub fn fork_tail(&mut self, lease: &mut Lease) -> Result<bool> {
        let Some(&tail) = lease.blocks.last() else {
            return Ok(false);
        };
        if !self.is_shared(tail) {
            return Ok(false);
        }
        let mut fresh = Lease::default();
        self.alloc(1, &mut fresh)?;
        let private = fresh.blocks.pop().expect("alloc(1) pushed a block");
        *lease.blocks.last_mut().expect("tail exists") = private;
        self.release_one(tail);
        Ok(true)
    }

    /// Shrink a lease to cover `slots` slots, dropping the excess
    /// references. The preemption path uses this to park a paused
    /// request at the cost of its committed tokens only; the blocks
    /// come back via [`ensure`](Self::ensure) on resume. Returns how
    /// many blocks actually became free (a popped block that is still
    /// shared with the cache stays live).
    pub fn shrink(&mut self, lease: &mut Lease, slots: usize, kv_layers: usize) -> usize {
        let want = self.blocks_for(slots, kv_layers);
        let mut released = 0usize;
        while lease.blocks.len() > want {
            let b = lease.blocks.pop().expect("len checked");
            if self.release_one(b) {
                released += 1;
            }
        }
        released
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut pool = BlockPool::new(10, 16);
        let mut lease = Lease::default();
        pool.alloc(4, &mut lease).unwrap();
        assert_eq!(pool.available(), 6);
        assert_eq!(pool.leaked_blocks(), 4);
        pool.release(&mut lease);
        assert_eq!(pool.available(), 10);
        assert_eq!(pool.leaked_blocks(), 0);
        assert!(lease.blocks.is_empty());
    }

    #[test]
    fn exhaustion_is_an_error() {
        let mut pool = BlockPool::new(2, 16);
        let mut lease = Lease::default();
        assert!(pool.alloc(3, &mut lease).is_err());
        assert_eq!(pool.available(), 2); // nothing leaked
    }

    #[test]
    fn blocks_for_accounting() {
        let pool = BlockPool::new(100, 16);
        // 33 slots -> 3 blocks per plane; 6 layers * 2 (K,V) = 36
        assert_eq!(pool.blocks_for(33, 6), 36);
        // FastEagle (6 cascade layers) costs 6x EAGLE (1 layer):
        assert_eq!(pool.blocks_for(16, 6), 6 * pool.blocks_for(16, 1));
    }

    #[test]
    fn ensure_grows_incrementally() {
        let mut pool = BlockPool::new(100, 16);
        let mut lease = Lease::default();
        pool.ensure(&mut lease, 10, 1).unwrap();
        let n1 = lease.blocks.len();
        pool.ensure(&mut lease, 20, 1).unwrap();
        assert!(lease.blocks.len() > n1);
        pool.ensure(&mut lease, 20, 1).unwrap(); // idempotent
        assert_eq!(lease.blocks.len(), pool.blocks_for(20, 1));
        pool.release(&mut lease);
    }

    #[test]
    fn shrink_then_ensure_roundtrips() {
        let mut pool = BlockPool::new(100, 16);
        let mut lease = Lease::default();
        // full lease for 64 slots, then shrink to 20 committed slots
        pool.ensure(&mut lease, 64, 2).unwrap();
        let full = lease.blocks.len();
        let released = pool.shrink(&mut lease, 20, 2);
        assert_eq!(lease.blocks.len(), pool.blocks_for(20, 2));
        assert_eq!(released, full - pool.blocks_for(20, 2));
        assert!(released > 0);
        // shrinking below never over-releases; ensure grows back exactly
        assert_eq!(pool.shrink(&mut lease, 20, 2), 0);
        pool.ensure(&mut lease, 64, 2).unwrap();
        assert_eq!(lease.blocks.len(), full);
        pool.release(&mut lease);
        assert_eq!(pool.available(), 100);
    }

    #[test]
    fn no_double_lease_of_blocks() {
        let mut pool = BlockPool::new(8, 16);
        let mut a = Lease::default();
        let mut b = Lease::default();
        pool.alloc(4, &mut a).unwrap();
        pool.alloc(4, &mut b).unwrap();
        let mut all: Vec<u32> = a.blocks.iter().chain(b.blocks.iter()).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 8);
        pool.release(&mut a);
        pool.release(&mut b);
    }

    #[test]
    fn retained_blocks_free_only_on_last_release() {
        let mut pool = BlockPool::new(8, 16);
        let mut a = Lease::default();
        pool.alloc(3, &mut a).unwrap();
        // cache-style second holder: same capacity, two references
        let mut b = Lease::default();
        pool.retain(&a.blocks);
        b.blocks.extend_from_slice(&a.blocks);
        assert_eq!(pool.available(), 5, "sharing charges capacity once");
        assert!(a.blocks.iter().all(|&blk| pool.is_shared(blk)));
        assert_eq!(pool.refcount(a.blocks[0]), 2);
        pool.release(&mut a);
        assert_eq!(pool.available(), 5, "blocks still held by the share");
        assert_eq!(pool.leaked_blocks(), 3);
        assert!(b.blocks.iter().all(|&blk| !pool.is_shared(blk)));
        pool.release(&mut b);
        assert_eq!(pool.available(), 8);
        assert_eq!(pool.leaked_blocks(), 0);
    }

    #[test]
    fn fork_tail_is_copy_on_write() {
        let mut pool = BlockPool::new(8, 16);
        let mut owner = Lease::default();
        pool.alloc(2, &mut owner).unwrap();
        let mut writer = Lease::default();
        pool.retain(&owner.blocks);
        writer.blocks.extend_from_slice(&owner.blocks);
        let shared_tail = *writer.blocks.last().unwrap();
        // writer must not append into the shared tail: fork it
        assert!(pool.fork_tail(&mut writer).unwrap());
        let private_tail = *writer.blocks.last().unwrap();
        assert_ne!(private_tail, shared_tail);
        assert!(!pool.is_shared(shared_tail), "share dropped by the fork");
        assert!(!pool.is_shared(private_tail));
        assert_eq!(owner.blocks[1], shared_tail, "reader keeps the original");
        // private tails don't fork again
        assert!(!pool.fork_tail(&mut writer).unwrap());
        pool.release(&mut owner);
        pool.release(&mut writer);
        assert_eq!(pool.available(), 8);
    }

    #[test]
    fn shrink_of_shared_blocks_frees_nothing_until_last_holder() {
        let mut pool = BlockPool::new(8, 16);
        let mut owner = Lease::default();
        pool.alloc(4, &mut owner).unwrap();
        let mut holder = Lease::default();
        pool.retain(&owner.blocks[..2]);
        holder.blocks.extend_from_slice(&owner.blocks[..2]);
        // shrink the owner to 0 slots: 2 private blocks free, 2 shared stay
        let freed = pool.shrink(&mut owner, 0, 1);
        assert_eq!(freed, 2);
        assert_eq!(pool.available(), 6);
        pool.release(&mut owner);
        pool.release(&mut holder);
        assert_eq!(pool.available(), 8);
    }

    #[test]
    #[should_panic(expected = "live blocks")]
    fn dropping_a_live_lease_panics_in_debug() {
        let mut pool = BlockPool::new(8, 16);
        let mut lease = Lease::default();
        pool.alloc(1, &mut lease).unwrap();
        drop(lease); // leak: debug builds refuse
    }
}
