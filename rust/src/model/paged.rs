//! Paged KV accounting: a block allocator in the vLLM mold.
//!
//! The PJRT executables use dense per-request KV tensors (fixed shapes),
//! so the paged layer manages *capacity*, not addresses: admission
//! control and preemption in the continuous-batching coordinator are
//! driven by block availability. This is what produces the paper's
//! Table-3 memory-pressure effect — FastEagle's cascade keeps N drafter
//! KV layers alive per request vs EAGLE's 1, so its per-request block
//! cost is higher and throughput saturates at smaller batch sizes.

use anyhow::{bail, Result};

#[derive(Debug)]
pub struct BlockPool {
    block_slots: usize,
    /// recycled block ids (released leases)
    free: Vec<u32>,
    /// first never-issued id: ids `next..total` exist only as capacity,
    /// so an effectively-unbounded pool (the engine's default is
    /// `usize::MAX / 4` blocks) costs nothing until leased
    next: usize,
    total: usize,
}

/// Blocks leased to one request; freed by returning to the pool.
#[derive(Debug, Default)]
pub struct Lease {
    pub blocks: Vec<u32>,
}

impl BlockPool {
    pub fn new(total_blocks: usize, block_slots: usize) -> BlockPool {
        assert!(block_slots > 0);
        BlockPool { block_slots, free: Vec::new(), next: 0, total: total_blocks }
    }

    pub fn block_slots(&self) -> usize {
        self.block_slots
    }

    pub fn available(&self) -> usize {
        self.free.len() + (self.total - self.next)
    }

    pub fn total(&self) -> usize {
        self.total
    }

    /// Blocks needed to hold `slots` KV rows across `kv_layers` layers
    /// (each layer stores K and V).
    pub fn blocks_for(&self, slots: usize, kv_layers: usize) -> usize {
        let per_layer = slots.div_ceil(self.block_slots);
        per_layer * kv_layers * 2
    }

    pub fn can_alloc(&self, n: usize) -> bool {
        self.available() >= n
    }

    pub fn alloc(&mut self, n: usize, lease: &mut Lease) -> Result<()> {
        if self.available() < n {
            bail!("block pool exhausted: want {n}, have {}", self.available());
        }
        for _ in 0..n {
            match self.free.pop() {
                Some(b) => lease.blocks.push(b),
                None => {
                    // ids are capacity accounting, not addresses — a
                    // wrap past u32 would need >4e9 live blocks
                    lease.blocks.push(self.next as u32);
                    self.next += 1;
                }
            }
        }
        Ok(())
    }

    /// Grow a lease to cover `slots` slots (allocating only the delta).
    pub fn ensure(
        &mut self,
        lease: &mut Lease,
        slots: usize,
        kv_layers: usize,
    ) -> Result<()> {
        let want = self.blocks_for(slots, kv_layers);
        if lease.blocks.len() < want {
            let delta = want - lease.blocks.len();
            self.alloc(delta, lease)?;
        }
        Ok(())
    }

    pub fn release(&mut self, lease: &mut Lease) {
        self.free.append(&mut lease.blocks);
        debug_assert!(self.free.len() <= self.total);
    }

    /// Shrink a lease to cover `slots` slots, returning the excess
    /// blocks to the pool. The preemption path uses this to park a
    /// paused request at the cost of its committed tokens only; the
    /// blocks come back via [`ensure`](Self::ensure) on resume.
    /// Returns how many blocks were released.
    pub fn shrink(&mut self, lease: &mut Lease, slots: usize, kv_layers: usize) -> usize {
        let want = self.blocks_for(slots, kv_layers);
        let mut released = 0usize;
        while lease.blocks.len() > want {
            self.free.push(lease.blocks.pop().unwrap());
            released += 1;
        }
        debug_assert!(self.free.len() <= self.total);
        released
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut pool = BlockPool::new(10, 16);
        let mut lease = Lease::default();
        pool.alloc(4, &mut lease).unwrap();
        assert_eq!(pool.available(), 6);
        pool.release(&mut lease);
        assert_eq!(pool.available(), 10);
        assert!(lease.blocks.is_empty());
    }

    #[test]
    fn exhaustion_is_an_error() {
        let mut pool = BlockPool::new(2, 16);
        let mut lease = Lease::default();
        assert!(pool.alloc(3, &mut lease).is_err());
        assert_eq!(pool.available(), 2); // nothing leaked
    }

    #[test]
    fn blocks_for_accounting() {
        let pool = BlockPool::new(100, 16);
        // 33 slots -> 3 blocks per plane; 6 layers * 2 (K,V) = 36
        assert_eq!(pool.blocks_for(33, 6), 36);
        // FastEagle (6 cascade layers) costs 6x EAGLE (1 layer):
        assert_eq!(pool.blocks_for(16, 6), 6 * pool.blocks_for(16, 1));
    }

    #[test]
    fn ensure_grows_incrementally() {
        let mut pool = BlockPool::new(100, 16);
        let mut lease = Lease::default();
        pool.ensure(&mut lease, 10, 1).unwrap();
        let n1 = lease.blocks.len();
        pool.ensure(&mut lease, 20, 1).unwrap();
        assert!(lease.blocks.len() > n1);
        pool.ensure(&mut lease, 20, 1).unwrap(); // idempotent
        assert_eq!(lease.blocks.len(), pool.blocks_for(20, 1));
        pool.release(&mut lease);
    }

    #[test]
    fn shrink_then_ensure_roundtrips() {
        let mut pool = BlockPool::new(100, 16);
        let mut lease = Lease::default();
        // full lease for 64 slots, then shrink to 20 committed slots
        pool.ensure(&mut lease, 64, 2).unwrap();
        let full = lease.blocks.len();
        let released = pool.shrink(&mut lease, 20, 2);
        assert_eq!(lease.blocks.len(), pool.blocks_for(20, 2));
        assert_eq!(released, full - pool.blocks_for(20, 2));
        assert!(released > 0);
        // shrinking below never over-releases; ensure grows back exactly
        assert_eq!(pool.shrink(&mut lease, 20, 2), 0);
        pool.ensure(&mut lease, 64, 2).unwrap();
        assert_eq!(lease.blocks.len(), full);
        pool.release(&mut lease);
        assert_eq!(pool.available(), 100);
    }

    #[test]
    fn no_double_lease_of_blocks() {
        let mut pool = BlockPool::new(8, 16);
        let mut a = Lease::default();
        let mut b = Lease::default();
        pool.alloc(4, &mut a).unwrap();
        pool.alloc(4, &mut b).unwrap();
        let mut all: Vec<u32> = a.blocks.iter().chain(b.blocks.iter()).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 8);
    }
}
