//! Model layer: spec, tokenizer, KV state, paged accounting, and the
//! single-request target interface over the AOT executables.

pub mod kvcache;
pub mod paged;
pub mod spec;
pub mod target;
pub mod tokenizer;

pub use kvcache::{KvCache, KvLayout};
pub use paged::{BlockPool, Lease};
pub use spec::ModelSpec;
pub use target::{build_mask, MaskRow, PrefillOut, TargetModel, VerifyOut, NEG};
pub use tokenizer::Tokenizer;
