//! Target-model interface: chunked prefill + tree/chain verification over
//! the AOT executables (`tgt_m{M}`), with explicit mask construction.
//!
//! Masks are additive [1, T, S] tensors built here from `MaskRow`
//! descriptors: each row sees `[0, prefix_upto)` plus an explicit set of
//! extra absolute slots (its tree ancestors in the temp region). Padded
//! rows (the lowered executables have fixed T) see only slot 0 so their
//! softmax stays finite; their outputs and KV writes are dead and are
//! rolled back / overwritten by construction.

use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::runtime::registry::ArtifactStore;
use crate::runtime::tensor::HostTensor;
use crate::runtime::BoundExec;

use super::kvcache::KvCache;
use super::spec::ModelSpec;

pub const NEG: f32 = -1e9;

/// Visibility of one verify/prefill row.
#[derive(Debug, Clone, Default)]
pub struct MaskRow {
    /// row sees absolute slots [0, prefix_upto)
    pub prefix_upto: usize,
    /// plus these absolute slots (tree ancestors / self)
    pub extra: Vec<usize>,
}

/// Build the additive [1, t, s] mask tensor from row descriptors.
/// Rows beyond `rows.len()` are padding and see only slot 0.
pub fn build_mask(t: usize, s: usize, rows: &[MaskRow]) -> HostTensor {
    let mut data = vec![NEG; t * s];
    for (i, row) in rows.iter().enumerate() {
        let base = i * s;
        let upto = row.prefix_upto.min(s);
        for v in &mut data[base..base + upto] {
            *v = 0.0;
        }
        for &e in &row.extra {
            if e < s {
                data[base + e] = 0.0;
            }
        }
    }
    for i in rows.len()..t {
        data[i * s] = 0.0; // padding rows: slot 0 keeps softmax finite
    }
    HostTensor::f32(vec![1, t, s], data)
}

pub struct PrefillOut {
    /// [prompt_len, feat_dim] multi-level features of every prompt token
    pub feats: Vec<f32>,
    /// [vocab] logits at the last prompt token
    pub last_logits: Vec<f32>,
}

pub struct VerifyOut {
    /// [n, vocab] logits of the n real (non-pad) rows
    pub logits: Vec<f32>,
    /// [n, feat_dim] features of the n real rows (empty if the model
    /// variant exports none, e.g. the SpS draft LM)
    pub feats: Vec<f32>,
}

/// Single-request (B=1) interface over a target-style model — used both
/// for the real target (`tgt_*`, with feature taps) and the SpS draft LM
/// (`sps_*`, logits only).
pub struct TargetModel {
    pub spec: ModelSpec,
    store: Rc<ArtifactStore>,
    exec_prefix: &'static str,
    wset: &'static str,
    with_feats: bool,
    kv_layers: usize,
    d_model: usize,
}

impl TargetModel {
    pub fn open(store: Rc<ArtifactStore>) -> Result<TargetModel> {
        let spec = ModelSpec::parse(&store.spec_json()?)?;
        // engine contract: every reachable draft plan must have a
        // lowered verify lane — fail at open, not mid-generation
        let report = crate::runtime::contract::check_single(&spec);
        report.ensure_ok()?;
        for w in report.warnings() {
            eprintln!("[{}] contract: {w}", spec.name);
        }
        let (n_layers, d_model) = (spec.n_layers, spec.d_model);
        Ok(TargetModel {
            spec,
            store,
            exec_prefix: "tgt",
            wset: "target",
            with_feats: true,
            kv_layers: n_layers,
            d_model,
        })
    }

    /// The SpS baseline's separate draft LM, sharing the artifact dir.
    pub fn open_sps(store: Rc<ArtifactStore>) -> Result<TargetModel> {
        let spec = ModelSpec::parse(&store.spec_json()?)?;
        let (n_layers, d_model) = (spec.sps.n_layers, spec.sps.d_model);
        Ok(TargetModel {
            spec,
            store,
            exec_prefix: "sps",
            wset: "sps",
            with_feats: false,
            kv_layers: n_layers,
            d_model,
        })
    }

    pub fn feat_dim(&self) -> usize {
        if self.with_feats {
            self.spec.feat_dim
        } else {
            0
        }
    }

    pub fn vocab(&self) -> usize {
        self.spec.vocab
    }

    /// Hidden width of this model variant (the SpS LM differs from the
    /// target).
    pub fn d_model(&self) -> usize {
        self.d_model
    }

    fn kv_heads(&self) -> (usize, usize) {
        if self.with_feats {
            (self.spec.n_kv_heads, self.spec.head_dim)
        } else {
            (self.spec.sps.n_kv_heads, self.spec.sps.head_dim)
        }
    }

    pub fn new_kv(&self) -> Result<KvCache> {
        let (kh, hd) = self.kv_heads();
        KvCache::zeros(vec![self.kv_layers, 2, 1, self.spec.max_seq, kh, hd])
    }

    /// Verify-M variants this model exports (e.g. [1, 2, 5, 6, 18, 32]).
    fn m_for(&self, n: usize) -> Result<usize> {
        if self.exec_prefix == "sps" {
            // sps exports m1/m8/m32
            for m in [1usize, 8, 32] {
                if m >= n {
                    return Ok(m);
                }
            }
            bail!("no sps executable fits {n} rows");
        }
        self.spec
            .verify_m_for(n)
            .with_context(|| format!("no {} executable fits {n} rows", self.exec_prefix))
    }

    fn exec(&self, m: usize) -> Result<Rc<BoundExec>> {
        self.store
            .bind(&format!("{}_m{}", self.exec_prefix, m), self.wset)
    }

    /// Run one fixed-shape call: `tokens`/`positions`/`rows` may be
    /// shorter than the executable's M — they are padded here. The new KV
    /// rows land at `kv.len(0)`; the caller decides what to keep
    /// (set_len / compact / rollback).
    pub fn step(
        &self,
        kv: &mut KvCache,
        tokens: &[i32],
        positions: &[i32],
        rows: &[MaskRow],
    ) -> Result<VerifyOut> {
        let n = tokens.len();
        assert_eq!(positions.len(), n);
        assert_eq!(rows.len(), n);
        let m = self.m_for(n)?;
        let s = self.spec.max_seq;
        let cache_len = kv.len(0);
        if cache_len + m > s {
            bail!("kv overflow: cache_len {cache_len} + m {m} > {s}");
        }
        let mut toks = vec![self.spec.pad; m];
        toks[..n].copy_from_slice(tokens);
        let mut pos = vec![0i32; m];
        for (i, &p) in positions.iter().enumerate() {
            pos[i] = p.min(s as i32 - 1);
        }
        let mask = build_mask(m, s, rows);
        let tokens_t = HostTensor::i32(vec![1, m], toks);
        let pos_t = HostTensor::i32(vec![1, m], pos);
        let cl_t = HostTensor::i32(vec![1], vec![cache_len as i32]);

        let exec = self.exec(m)?;
        let outs = exec.call(
            &self.store.runtime,
            &[
                ("tokens", &tokens_t),
                ("positions", &pos_t),
                ("mask", &mask),
                ("cache_len", &cl_t),
                ("kv", kv.tensor()),
            ],
        )?;
        let li = exec.out_idx("logits")?;
        let ki = exec.out_idx("kv")?;
        let v = self.spec.vocab;
        let logits = outs[li].as_f32()?[..n * v].to_vec();
        let feats = if self.with_feats {
            let fi = exec.out_idx("feats")?;
            outs[fi].as_f32()?[..n * self.spec.feat_dim].to_vec()
        } else {
            Vec::new()
        };
        // take the kv output (clone-free: move out of the Vec)
        let mut outs = outs;
        kv.update_from(outs.swap_remove(ki))?;
        Ok(VerifyOut { logits, feats })
    }

    /// Chunked prompt ingestion. Returns features for every prompt token
    /// (the drafters' anchor inputs) and the last token's logits.
    pub fn prefill(&self, kv: &mut KvCache, tokens: &[i32]) -> Result<PrefillOut> {
        let chunk = self.spec.prefill_chunk;
        let fd = self.feat_dim();
        let v = self.spec.vocab;
        let mut feats = Vec::with_capacity(tokens.len() * fd);
        let mut last_logits = vec![0.0f32; v];
        let mut base = 0usize;
        while base < tokens.len() {
            let n = (tokens.len() - base).min(chunk);
            let toks = &tokens[base..base + n];
            let positions: Vec<i32> = (base..base + n).map(|p| p as i32).collect();
            let rows: Vec<MaskRow> = (0..n)
                .map(|i| MaskRow { prefix_upto: base + i + 1, extra: vec![] })
                .collect();
            let out = self.step(kv, toks, &positions, &rows)?;
            let new_len = base + n;
            kv.set_len(0, new_len);
            if fd > 0 {
                feats.extend_from_slice(&out.feats);
            }
            if new_len == tokens.len() {
                last_logits.copy_from_slice(&out.logits[(n - 1) * v..n * v]);
            }
            base = new_len;
        }
        Ok(PrefillOut { feats, last_logits })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_rows() {
        let m = build_mask(3, 5, &[
            MaskRow { prefix_upto: 2, extra: vec![4] },
            MaskRow { prefix_upto: 0, extra: vec![2] },
        ]);
        let d = m.as_f32().unwrap();
        // row 0: slots 0,1,4 visible
        assert_eq!(&d[0..5], &[0.0, 0.0, NEG, NEG, 0.0]);
        // row 1: slot 2 only
        assert_eq!(&d[5..10], &[NEG, NEG, 0.0, NEG, NEG]);
        // row 2 is padding: slot 0 only
        assert_eq!(&d[10..15], &[0.0, NEG, NEG, NEG, NEG]);
    }

    #[test]
    fn mask_clips_out_of_range() {
        let m = build_mask(1, 3, &[MaskRow { prefix_upto: 99, extra: vec![7] }]);
        assert_eq!(m.as_f32().unwrap(), &[0.0, 0.0, 0.0]);
    }
}
