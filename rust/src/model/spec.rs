//! Model spec: the dimensions/contract exported by `aot.py` as
//! `artifacts/<target>/spec.json`. Single source of truth shared with
//! the python side (`python/compile/configs.py`).

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct SpsDims {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub stands_for: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub taps: Vec<usize>,
    pub max_seq: usize,
    pub vocab: usize,
    pub feat_dim: usize,
    pub bos: i32,
    pub eos: i32,
    pub pad: i32,
    pub prefill_chunk: usize,
    pub draft_depth: usize,
    pub tree_top_k: usize,
    /// derived: default-plan draft nodes (`draft_depth * tree_top_k`)
    /// via `spec::plan::default_draft_nodes` — no longer read from the
    /// JSON, so the shape arithmetic has exactly one home
    pub tree_nodes: usize,
    /// the manifest's literal `tree_nodes` field, kept so the contract
    /// checker can warn when it disagrees with the derived value
    /// instead of discarding it silently
    pub tree_nodes_on_disk: Option<usize>,
    /// every executable name listed in the spec's inventory (used by
    /// the contract checker to confirm the artifacts exist on disk)
    pub executables: Vec<String>,
    pub medusa_heads: usize,
    pub sps_chain: usize,
    pub sps: SpsDims,
    pub drafter_sets: Vec<String>,
    pub batch_sizes: Vec<usize>,
    pub verify_ms: Vec<usize>,
    /// lowered batched verify variants: (batch, sorted verify-M list)
    /// from `tgt_m{M}_b{B}` executables
    pub verify_ms_by_batch: Vec<(usize, Vec<usize>)>,
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Json::as_usize)
        .with_context(|| format!("spec.json missing {key:?}"))
}

impl ModelSpec {
    pub fn parse(text: &str) -> Result<ModelSpec> {
        let v = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let sps = v.get("sps").context("spec.json missing sps")?;
        // executable inventory -> which verify-M variants exist, per
        // batch (tgt_m{M} at B=1, tgt_m{M}_b{B} on the batched lane)
        let mut verify_ms: Vec<usize> = Vec::new();
        let mut executables: Vec<String> = Vec::new();
        let mut by_batch: std::collections::BTreeMap<usize, Vec<usize>> = Default::default();
        if let Some(execs) = v.get("executables").and_then(Json::as_obj) {
            for name in execs.keys() {
                executables.push(name.clone());
                if let Some(rest) = name.strip_prefix("tgt_m") {
                    match rest.split_once("_b") {
                        None => {
                            if let Ok(m) = rest.parse::<usize>() {
                                verify_ms.push(m);
                            }
                        }
                        Some((m, b)) => {
                            if let (Ok(m), Ok(b)) = (m.parse::<usize>(), b.parse::<usize>()) {
                                by_batch.entry(b).or_default().push(m);
                            }
                        }
                    }
                }
            }
        }
        verify_ms.sort_unstable();
        verify_ms.dedup();
        executables.sort_unstable();
        let verify_ms_by_batch: Vec<(usize, Vec<usize>)> = by_batch
            .into_iter()
            .map(|(b, mut ms)| {
                ms.sort_unstable();
                ms.dedup();
                (b, ms)
            })
            .collect();
        Ok(ModelSpec {
            name: v.get("name").and_then(Json::as_str).context("name")?.to_string(),
            stands_for: v
                .get("stands_for")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            d_model: req_usize(&v, "d_model")?,
            n_layers: req_usize(&v, "n_layers")?,
            n_heads: req_usize(&v, "n_heads")?,
            n_kv_heads: req_usize(&v, "n_kv_heads")?,
            head_dim: req_usize(&v, "head_dim")?,
            ffn: req_usize(&v, "ffn")?,
            taps: v
                .get("taps")
                .and_then(Json::as_arr)
                .context("taps")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            max_seq: req_usize(&v, "max_seq")?,
            vocab: req_usize(&v, "vocab")?,
            feat_dim: req_usize(&v, "feat_dim")?,
            bos: v.get("bos").and_then(Json::as_i64).context("bos")? as i32,
            eos: v.get("eos").and_then(Json::as_i64).context("eos")? as i32,
            pad: v.get("pad").and_then(Json::as_i64).context("pad")? as i32,
            prefill_chunk: req_usize(&v, "prefill_chunk")?,
            draft_depth: req_usize(&v, "draft_depth")?,
            tree_top_k: req_usize(&v, "tree_top_k")?,
            tree_nodes: crate::spec::plan::default_draft_nodes(
                req_usize(&v, "draft_depth")?,
                req_usize(&v, "tree_top_k")?,
            ),
            tree_nodes_on_disk: v.get("tree_nodes").and_then(Json::as_usize),
            executables,
            medusa_heads: req_usize(&v, "medusa_heads")?,
            sps_chain: req_usize(&v, "sps_chain")?,
            sps: SpsDims {
                d_model: req_usize(sps, "d_model")?,
                n_layers: req_usize(sps, "n_layers")?,
                n_kv_heads: req_usize(sps, "n_kv_heads")?,
                head_dim: req_usize(sps, "head_dim")?,
            },
            drafter_sets: v
                .get("drafter_sets")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                .unwrap_or_default(),
            batch_sizes: v
                .get("batch_sizes")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_else(|| vec![1]),
            verify_ms,
            verify_ms_by_batch,
        })
    }

    /// KV dim per row (KH * hd).
    pub fn kv_row(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// f32 elements of the target KV cache for one request.
    pub fn target_kv_elems(&self) -> usize {
        self.n_layers * 2 * self.max_seq * self.kv_row()
    }

    /// f32 elements of a drafter's KV state for one request
    /// (`layers` = cascade depth for FastEagle, 1 for EAGLE, sps layers).
    pub fn drafter_kv_elems(&self, layers: usize) -> usize {
        layers * 2 * self.max_seq * self.kv_row()
    }

    /// Smallest lowered verify variant with at least `m` rows.
    pub fn verify_m_for(&self, m: usize) -> Option<usize> {
        self.verify_ms.iter().copied().find(|&v| v >= m)
    }

    /// Smallest lowered verify variant with at least `rows` rows on the
    /// `batch` lane — how the batched engine picks its per-step
    /// executable from the step's largest [`DraftPlan`] row count.
    pub fn verify_m_lowered(&self, rows: usize, batch: usize) -> Option<usize> {
        if batch <= 1 {
            return self.verify_m_for(rows);
        }
        self.verify_ms_by_batch
            .iter()
            .find(|(b, _)| *b == batch)
            .and_then(|(_, ms)| ms.iter().copied().find(|&m| m >= rows))
    }
}

/// Shared sample spec for unit tests across modules. (`tree_nodes` is
/// deliberately absent: the spec derives it from the default
/// `DraftPlan` shape.)
#[cfg(test)]
pub mod tests_sample {
    pub const SAMPLE: &str = r#"{
      "name": "base", "stands_for": "Vicuna-13B",
      "d_model": 192, "n_layers": 6, "n_heads": 6, "n_kv_heads": 2,
      "head_dim": 32, "ffn": 576, "taps": [1,3,5], "max_seq": 256,
      "vocab": 272, "feat_dim": 576, "bos": 256, "eos": 257, "pad": 258,
      "prefill_chunk": 32, "draft_depth": 6, "tree_top_k": 3,
      "medusa_heads": 4, "sps_chain": 5,
      "sps": {"d_model": 96, "n_layers": 2, "n_kv_heads": 1, "head_dim": 32},
      "drafter_sets": ["fasteagle", "eagle3"],
      "executables": {"tgt_m1": {}, "tgt_m18": {}, "tgt_m2_b4": {}, "tgt_m5_b4": {}},
      "batch_sizes": [1]
    }"#;
}

#[cfg(test)]
mod tests {
    use super::tests_sample::SAMPLE;
    use super::*;

    #[test]
    fn parses() {
        let s = ModelSpec::parse(SAMPLE).unwrap();
        assert_eq!(s.name, "base");
        assert_eq!(s.kv_row(), 64);
        assert_eq!(s.target_kv_elems(), 6 * 2 * 256 * 64);
        assert_eq!(s.verify_ms, vec![1, 18]);
        assert_eq!(s.verify_m_for(5), Some(18));
        assert_eq!(s.verify_m_for(1), Some(1));
        assert_eq!(s.verify_m_for(99), None);
    }

    #[test]
    fn tree_nodes_derives_from_the_default_plan() {
        let s = ModelSpec::parse(SAMPLE).unwrap();
        // no "tree_nodes" in the JSON: derived from depth x top-k
        assert_eq!(s.tree_nodes, 6 * 3);
        assert_eq!(
            s.tree_nodes,
            crate::spec::plan::DraftPlan::default_for(&s).draft_nodes()
        );
    }

    #[test]
    fn batched_verify_variants_parse_and_select() {
        let s = ModelSpec::parse(SAMPLE).unwrap();
        assert_eq!(s.verify_ms_by_batch, vec![(4, vec![2, 5])]);
        assert_eq!(s.verify_m_lowered(1, 4), Some(2));
        assert_eq!(s.verify_m_lowered(3, 4), Some(5));
        assert_eq!(s.verify_m_lowered(6, 4), None);
        assert_eq!(s.verify_m_lowered(9, 2), None, "no batch-2 executables");
        // batch 1 falls through to the unbatched inventory
        assert_eq!(s.verify_m_lowered(5, 1), Some(18));
    }
}
