//! Host-side KV cache state, the `kind: state` tensors threaded through
//! the PJRT executables ([L, 2, B, S, KH, hd] for the target,
//! [N, 2, B, C, KH, hd] for the FastEagle cascade, [2, B, C, KH, hd] for
//! EAGLE). The Rust coordinator owns acceptance-driven **compaction**
//! (move the accepted tree nodes' rows into the canonical prefix) and
//! **rollback** (discard temporary draft entries) — the executables only
//! ever append rows at `cache_len`.

use anyhow::{bail, Result};

use crate::runtime::tensor::HostTensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvLayout {
    /// product of dims before the batch axis (e.g. L*2)
    pub planes: usize,
    pub batch: usize,
    /// slot count (max_seq / context size)
    pub s: usize,
    /// f32 elements per slot row (KH * hd)
    pub row: usize,
}

impl KvLayout {
    /// Interpret a state-tensor shape of the canonical form
    /// [..planes.., B, S, KH, hd].
    pub fn from_shape(shape: &[usize]) -> Result<KvLayout> {
        if shape.len() < 4 {
            bail!("kv shape too short: {shape:?}");
        }
        let n = shape.len();
        let batch = shape[n - 4];
        let s = shape[n - 3];
        let row = shape[n - 2] * shape[n - 1];
        let planes: usize = shape[..n - 4].iter().product();
        Ok(KvLayout { planes, batch, s, row })
    }

    #[inline]
    pub fn offset(&self, plane: usize, b: usize, slot: usize) -> usize {
        ((plane * self.batch + b) * self.s + slot) * self.row
    }
}

#[derive(Debug, Clone)]
pub struct KvCache {
    tensor: HostTensor,
    pub layout: KvLayout,
    len: Vec<usize>,
}

impl KvCache {
    pub fn zeros(shape: Vec<usize>) -> Result<KvCache> {
        let layout = KvLayout::from_shape(&shape)?;
        Ok(KvCache {
            tensor: HostTensor::f32(shape.clone(), vec![0.0; shape.iter().product()]),
            layout,
            len: vec![0; layout.batch],
        })
    }

    pub fn tensor(&self) -> &HostTensor {
        &self.tensor
    }

    /// Replace contents with an executable's updated state output.
    pub fn update_from(&mut self, t: HostTensor) -> Result<()> {
        if t.shape != self.tensor.shape {
            bail!("kv update shape {:?} != {:?}", t.shape, self.tensor.shape);
        }
        self.tensor = t;
        Ok(())
    }

    pub fn len(&self, b: usize) -> usize {
        self.len[b]
    }

    pub fn set_len(&mut self, b: usize, l: usize) {
        assert!(l <= self.layout.s, "kv overflow: {l} > {}", self.layout.s);
        self.len[b] = l;
    }

    /// Discard entries beyond `l` (they stay as garbage; masks hide them).
    pub fn rollback(&mut self, b: usize, l: usize) {
        assert!(l <= self.len[b]);
        self.len[b] = l;
    }

    /// Keep only `kept` (ascending, relative to `base`) of the rows that
    /// were appended at `base`, packing them to `base..base+kept.len()`,
    /// and set the request length to `base + kept.len()`.
    ///
    /// This is the acceptance step: after tree verification the accepted
    /// path's rows (scattered across the M tree slots) become the
    /// canonical KV prefix.
    pub fn compact(&mut self, b: usize, base: usize, kept: &[usize]) -> Result<()> {
        for w in kept.windows(2) {
            if w[0] >= w[1] {
                bail!("kept slots must be ascending: {kept:?}");
            }
        }
        let lay = self.layout;
        if let Some(&last) = kept.last() {
            if base + last >= lay.s {
                bail!("compact out of range: base {base} + slot {last} >= {}", lay.s);
            }
        }
        let data = self.tensor.as_f32_mut()?;
        for plane in 0..lay.planes {
            for (i, &slot) in kept.iter().enumerate() {
                if slot == i {
                    continue; // already in place (kept ascending => src >= dst)
                }
                let src = lay.offset(plane, b, base + slot);
                let dst = lay.offset(plane, b, base + i);
                data.copy_within(src..src + lay.row, dst);
            }
        }
        self.len[b] = base + kept.len();
        Ok(())
    }

    /// Copy one request's rows from a single-request cache (`src`,
    /// batch=1) into batch slot `dst_b` of this cache. Used by the
    /// continuous batcher's admission lane: prefill runs on B=1
    /// executables, then the state moves into the batched tensors.
    pub fn copy_request_from(&mut self, dst_b: usize, src: &KvCache) -> Result<()> {
        let (dl, sl) = (self.layout, src.layout);
        if sl.batch != 1 || dl.planes != sl.planes || dl.row != sl.row || dl.s != sl.s {
            bail!("incompatible kv layouts: {dl:?} vs {sl:?}");
        }
        let n = src.len(0);
        let src_data = src.tensor.as_f32()?;
        let dst_data = self.tensor.as_f32_mut()?;
        for plane in 0..dl.planes {
            let so = sl.offset(plane, 0, 0);
            let doff = dl.offset(plane, dst_b, 0);
            dst_data[doff..doff + n * dl.row]
                .copy_from_slice(&src_data[so..so + n * sl.row]);
        }
        self.len[dst_b] = n;
        Ok(())
    }

    /// Copy one batch slot's canonical rows out into a fresh
    /// single-request (batch=1) cache of the same plane/row geometry —
    /// the inverse of [`copy_request_from`](Self::copy_request_from).
    /// Used by the scheduler's preemption path: a paused request's KV
    /// state is parked on the host so its batch lane (and the pool
    /// blocks beyond its committed prefix) can be handed to other work,
    /// then restored verbatim on resume (no recomputation).
    pub fn extract_request(&self, b: usize) -> Result<KvCache> {
        let lay = self.layout;
        if b >= lay.batch {
            bail!("extract_request: slot {b} out of range (batch {})", lay.batch);
        }
        let n = self.len(b);
        let mut shape = self.tensor.shape.clone();
        let batch_axis = shape.len() - 4;
        shape[batch_axis] = 1;
        let mut out = KvCache::zeros(shape)?;
        {
            let src_data = self.tensor.as_f32()?;
            let dst_data = out.tensor.as_f32_mut()?;
            let dl = out.layout;
            for plane in 0..lay.planes {
                let so = lay.offset(plane, b, 0);
                let doff = dl.offset(plane, 0, 0);
                dst_data[doff..doff + n * lay.row]
                    .copy_from_slice(&src_data[so..so + n * lay.row]);
            }
        }
        out.len[0] = n;
        Ok(out)
    }

    /// Copy `n` canonical rows (slots `start..start+n`) of batch lane
    /// `b` out as a dense `[planes, n, row]` buffer — the prefix
    /// cache's payload extraction at publish time.
    pub fn read_rows(&self, b: usize, start: usize, n: usize) -> Result<Vec<f32>> {
        let lay = self.layout;
        if b >= lay.batch || start + n > lay.s {
            bail!(
                "read_rows out of range: b {b} slots {start}..{} vs [B={}, S={}]",
                start + n,
                lay.batch,
                lay.s
            );
        }
        let data = self.tensor.as_f32()?;
        let mut out = Vec::with_capacity(lay.planes * n * lay.row);
        for plane in 0..lay.planes {
            let off = lay.offset(plane, b, start);
            out.extend_from_slice(&data[off..off + n * lay.row]);
        }
        Ok(out)
    }

    /// Inverse of [`read_rows`](Self::read_rows): write a dense
    /// `[planes, n, row]` buffer into slots `start..start+n` of lane
    /// `b` (prefix-cache adoption). Does not touch `len` — the caller
    /// sets it once the whole cached prefix is in place.
    pub fn write_rows(&mut self, b: usize, start: usize, n: usize, rows: &[f32]) -> Result<()> {
        let lay = self.layout;
        if b >= lay.batch || start + n > lay.s {
            bail!(
                "write_rows out of range: b {b} slots {start}..{} vs [B={}, S={}]",
                start + n,
                lay.batch,
                lay.s
            );
        }
        if rows.len() != lay.planes * n * lay.row {
            bail!(
                "write_rows payload {} != planes {} * n {} * row {}",
                rows.len(),
                lay.planes,
                n,
                lay.row
            );
        }
        let data = self.tensor.as_f32_mut()?;
        for plane in 0..lay.planes {
            let off = lay.offset(plane, b, start);
            let src = plane * n * lay.row;
            data[off..off + n * lay.row].copy_from_slice(&rows[src..src + n * lay.row]);
        }
        Ok(())
    }

    /// Raw mutable data access (tests and synthetic-state setup).
    pub fn tensor_mut_for_tests(&mut self) -> &mut [f32] {
        self.tensor.as_f32_mut().unwrap()
    }

    /// Debug/test accessor: one row (plane, batch, slot).
    pub fn row(&self, plane: usize, b: usize, slot: usize) -> &[f32] {
        let off = self.layout.offset(plane, b, slot);
        &self.tensor.as_f32().unwrap()[off..off + self.layout.row]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled_cache() -> KvCache {
        // [2 planes(=L*2 collapsed), B=2, S=4, KH=1, hd=2] -> row=2
        let shape = vec![2, 2, 4, 1, 2];
        let mut kv = KvCache::zeros(shape).unwrap();
        {
            let data = kv.tensor.as_f32_mut().unwrap();
            for (i, v) in data.iter_mut().enumerate() {
                *v = i as f32;
            }
        }
        kv
    }

    #[test]
    fn layout_from_shape() {
        let l = KvLayout::from_shape(&[6, 2, 1, 256, 2, 32]).unwrap();
        assert_eq!(l.planes, 12);
        assert_eq!(l.batch, 1);
        assert_eq!(l.s, 256);
        assert_eq!(l.row, 64);
        assert!(KvLayout::from_shape(&[1, 2]).is_err());
    }

    #[test]
    fn compact_moves_rows() {
        let mut kv = filled_cache();
        let orig_p0_b1_s3 = kv.row(0, 1, 3).to_vec();
        let orig_p1_b1_s1 = kv.row(1, 1, 1).to_vec();
        // at base=1, keep appended slots {0, 2} (absolute slots 1 and 3)
        kv.compact(1, 1, &[0, 2]).unwrap();
        assert_eq!(kv.len(1), 3);
        // slot base+1 (abs 2) now holds what was at abs slot 3
        assert_eq!(kv.row(0, 1, 2), orig_p0_b1_s3.as_slice());
        // slot base+0 unchanged
        assert_eq!(kv.row(1, 1, 1), orig_p1_b1_s1.as_slice());
        // other batch untouched
        let fresh = filled_cache();
        assert_eq!(kv.row(0, 0, 3), fresh.row(0, 0, 3));
    }

    #[test]
    fn compact_rejects_unsorted() {
        let mut kv = filled_cache();
        assert!(kv.compact(0, 0, &[2, 1]).is_err());
        assert!(kv.compact(0, 2, &[0, 5]).is_err()); // out of range
    }

    #[test]
    fn extract_then_copy_back_roundtrips() {
        let mut kv = filled_cache();
        kv.set_len(1, 3);
        let parked = kv.extract_request(1).unwrap();
        assert_eq!(parked.layout.batch, 1);
        assert_eq!(parked.len(0), 3);
        for plane in 0..2 {
            for slot in 0..3 {
                assert_eq!(parked.row(plane, 0, slot), kv.row(plane, 1, slot));
            }
        }
        // wipe the lane, then restore — rows must come back verbatim
        let reference: Vec<Vec<f32>> =
            (0..2).flat_map(|p| (0..3).map(move |s| (p, s))).map(|(p, s)| kv.row(p, 1, s).to_vec()).collect();
        kv.set_len(1, 0);
        {
            let lay = kv.layout;
            let data = kv.tensor_mut_for_tests();
            for plane in 0..lay.planes {
                let off = lay.offset(plane, 1, 0);
                for v in &mut data[off..off + 4 * lay.row] {
                    *v = 0.0;
                }
            }
        }
        kv.copy_request_from(1, &parked).unwrap();
        assert_eq!(kv.len(1), 3);
        let mut i = 0;
        for p in 0..2 {
            for s in 0..3 {
                assert_eq!(kv.row(p, 1, s), reference[i].as_slice());
                i += 1;
            }
        }
    }

    #[test]
    fn rollback_shrinks() {
        let mut kv = filled_cache();
        kv.set_len(0, 4);
        kv.rollback(0, 2);
        assert_eq!(kv.len(0), 2);
    }

    #[test]
    #[should_panic]
    fn overflow_panics() {
        let mut kv = filled_cache();
        kv.set_len(0, 5);
    }

    #[test]
    fn read_write_rows_roundtrips() {
        let kv = filled_cache();
        // rows 1..3 of lane 1, both planes
        let rows = kv.read_rows(1, 1, 2).unwrap();
        assert_eq!(rows.len(), 2 * 2 * 2); // planes * n * row
        assert_eq!(&rows[..2], kv.row(0, 1, 1));
        assert_eq!(&rows[2..4], kv.row(0, 1, 2));
        assert_eq!(&rows[4..6], kv.row(1, 1, 1));
        // write them into lane 0 at a different offset
        let mut dst = filled_cache();
        dst.write_rows(0, 2, 2, &rows).unwrap();
        assert_eq!(dst.row(0, 0, 2), kv.row(0, 1, 1));
        assert_eq!(dst.row(0, 0, 3), kv.row(0, 1, 2));
        assert_eq!(dst.row(1, 0, 2), kv.row(1, 1, 1));
        // other lane untouched
        assert_eq!(dst.row(0, 1, 2), kv.row(0, 1, 2));
        // bounds and payload-size errors
        assert!(kv.read_rows(1, 3, 2).is_err());
        assert!(dst.write_rows(0, 0, 2, &rows[..3]).is_err());
        assert!(dst.write_rows(2, 0, 1, &rows[..4]).is_err());
    }
}
