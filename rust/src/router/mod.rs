//! Multi-replica serving tier: a standalone router process that speaks
//! the same JSON-lines protocol as `coordinator/server.rs` and fans
//! requests out over a fleet of replica servers.
//!
//! The router assigns every generation request a global id (injected
//! into the forwarded line as `"id"`, which replicas honor), picks a
//! replica through a pluggable [`RoutePolicy`] fed by periodic replica
//! `stats` polls, and streams the replica's reply lines back to the
//! client **byte-for-byte** — a client talking through the router sees
//! exactly the frames and final response it would see talking to the
//! replica directly.
//!
//! Failure semantics (exactly-once token delivery):
//! - a replica that dies before delivering any line: the request is
//!   retried on a survivor (`fe_router_retries_total`), at most
//!   `max_retries` times;
//! - a replica that dies mid-stream (frames already forwarded): the
//!   client gets a structured `{"id", "error", "replica",
//!   "frames_delivered"}` line — never a silent hang, never replayed
//!   frames;
//! - a dead replica is probed with exponential backoff and rejoins the
//!   rotation when its `stats` answer again.
//!
//! Router commands (same framing as a replica):
//!   {"cmd":"stats"}    -> per-replica table + fleet aggregates
//!   {"cmd":"metrics"}  -> every replica's Prometheus exposition merged
//!                         into one page (samples labeled replica="K")
//!                         + fe_router_* series, "# EOF"-terminated
//!   {"cmd":"cancel","req":N} -> forwarded to the replica running N
//!   {"cmd":"drain"}    -> forwarded to every alive replica
//!   {"cmd":"shutdown"} -> stops the router (replicas keep running;
//!                         `fasteagle route --spawn` shuts its spawned
//!                         replicas down itself)

#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod metrics;
pub mod policy;
pub mod replica;

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::Json;

pub use metrics::RouterMetrics;
pub use policy::{make_policy, ReplicaView, RoutePolicy};
pub use replica::{query_json, query_line, query_text, Replica, ReplicaStats};

pub struct RouterConfig {
    pub addr: String,
    /// replica `stats` poll cadence
    pub poll_ms: u64,
    /// reroute budget per request (failures before any reply line)
    pub max_retries: usize,
    /// read timeout against a replica while forwarding; a replica
    /// silent for this long counts as failed
    pub forward_timeout_ms: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            addr: "127.0.0.1:7400".into(),
            poll_ms: 200,
            max_retries: 2,
            forward_timeout_ms: 120_000,
        }
    }
}

/// How one forward attempt against a replica ended.
enum ForwardResult {
    /// final response line delivered to the client
    Done,
    /// replica answered "server draining" before any token: retryable
    /// without marking it dead
    Drained,
    /// connection failed or closed early; `frames` lines were already
    /// forwarded to the client
    Failed { frames: usize },
}

pub struct Router {
    cfg: RouterConfig,
    replicas: Vec<Arc<Replica>>,
    policy: Mutex<Box<dyn RoutePolicy>>,
    policy_name: &'static str,
    pub metrics: Arc<RouterMetrics>,
    next_id: AtomicU64,
    /// global request id -> replica index, for cancel routing
    inflight: Mutex<HashMap<u64, usize>>,
    shutdown: Arc<AtomicBool>,
    started: Instant,
}

impl Router {
    pub fn new(
        cfg: RouterConfig,
        replica_addrs: Vec<String>,
        policy: Box<dyn RoutePolicy>,
    ) -> Router {
        let replicas = replica_addrs
            .into_iter()
            .enumerate()
            .map(|(i, addr)| Arc::new(Replica::new(addr, i)))
            .collect();
        Router {
            cfg,
            replicas,
            policy_name: policy.name(),
            policy: Mutex::new(policy),
            metrics: Arc::new(RouterMetrics::default()),
            next_id: AtomicU64::new(1),
            inflight: Mutex::new(HashMap::new()),
            shutdown: Arc::new(AtomicBool::new(false)),
            started: Instant::now(),
        }
    }

    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }

    /// Accept clients until a shutdown command. The poll thread keeps
    /// replica liveness and load fresh; each client connection gets its
    /// own thread, like the replica server.
    pub fn serve(self: &Arc<Router>) -> Result<()> {
        let listener = TcpListener::bind(&self.cfg.addr)
            .with_context(|| format!("bind {}", self.cfg.addr))?;
        self.serve_on(listener)
    }

    /// [`serve`](Self::serve) over a pre-bound listener (tests and
    /// embedders that want the OS to pick the port).
    pub fn serve_on(self: &Arc<Router>, listener: TcpListener) -> Result<()> {
        listener.set_nonblocking(true)?;
        crate::log_info!(
            "routing {} replicas (policy={}) on {}",
            self.replicas.len(),
            self.policy_name,
            self.cfg.addr
        );
        let poller = {
            let rt = Arc::clone(self);
            std::thread::spawn(move || {
                while !rt.shutdown.load(Ordering::Relaxed) {
                    for r in &rt.replicas {
                        r.poll(Duration::from_millis(1000));
                    }
                    std::thread::sleep(Duration::from_millis(rt.cfg.poll_ms));
                }
            })
        };
        let mut conns = Vec::new();
        while !self.shutdown.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let rt = Arc::clone(self);
                    conns.push(std::thread::spawn(move || {
                        let _ = handle_client(rt, stream);
                    }));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
        // stop accepting before waiting on in-flight connections
        drop(listener);
        for c in conns {
            let _ = c.join();
        }
        let _ = poller.join();
        Ok(())
    }

    /// Per-replica table + fleet aggregates for `{"cmd":"stats"}`.
    fn stats_json(&self) -> Json {
        let mut rows = Vec::new();
        let (mut alive, mut active, mut queued) = (0usize, 0usize, 0usize);
        for r in &self.replicas {
            let s = r.stats();
            if r.is_alive() {
                alive += 1;
                active += s.active;
                queued += s.queued;
            }
            rows.push(Json::obj(vec![
                ("replica", Json::num(r.index as f64)),
                ("replica_id", Json::num(s.replica_id as f64)),
                ("addr", Json::str(&r.addr)),
                ("alive", Json::Bool(r.is_alive())),
                ("draining", Json::Bool(s.draining)),
                ("active", Json::num(s.active as f64)),
                ("queued", Json::num(s.queued as f64)),
                ("uptime_ms", Json::num(s.uptime_ms as f64)),
                ("requests_done", Json::num(s.requests_done as f64)),
                ("inflight", Json::num(r.inflight.load(Ordering::Relaxed) as f64)),
                ("forwarded", Json::num(r.forwarded.load(Ordering::Relaxed) as f64)),
                ("failures", Json::num(r.failures.load(Ordering::Relaxed) as f64)),
            ]));
        }
        let m = &self.metrics;
        Json::obj(vec![
            ("router", Json::Bool(true)),
            ("policy", Json::str(self.policy_name)),
            ("uptime_ms", Json::num(self.started.elapsed().as_millis() as f64)),
            ("replicas", Json::Arr(rows)),
            ("alive", Json::num(alive as f64)),
            ("fleet_active", Json::num(active as f64)),
            ("fleet_queued", Json::num(queued as f64)),
            ("requests", Json::num(m.requests.load(Ordering::Relaxed) as f64)),
            ("retries", Json::num(m.retries.load(Ordering::Relaxed) as f64)),
            (
                "midstream_failures",
                Json::num(m.midstream_failures.load(Ordering::Relaxed) as f64),
            ),
            ("cancels", Json::num(m.cancels.load(Ordering::Relaxed) as f64)),
        ])
    }
}

/// Forward one request line to `addr` and stream every reply line back
/// to the client verbatim. `Err` means the *client* connection broke
/// (abort the connection); replica-side failures come back as
/// [`ForwardResult::Failed`] for the retry logic.
fn forward_once(
    addr: &str,
    line: &str,
    client: &mut TcpStream,
    timeout: Duration,
) -> Result<ForwardResult> {
    let Ok(stream) = TcpStream::connect(addr) else {
        return Ok(ForwardResult::Failed { frames: 0 });
    };
    if stream.set_read_timeout(Some(timeout)).is_err() {
        return Ok(ForwardResult::Failed { frames: 0 });
    }
    let Ok(mut w) = stream.try_clone() else {
        return Ok(ForwardResult::Failed { frames: 0 });
    };
    if writeln!(w, "{line}").is_err() {
        return Ok(ForwardResult::Failed { frames: 0 });
    }
    let mut reader = BufReader::new(stream);
    let mut frames = 0usize;
    loop {
        let mut l = String::new();
        match reader.read_line(&mut l) {
            Ok(0) | Err(_) => return Ok(ForwardResult::Failed { frames }),
            Ok(_) => {}
        }
        let v = Json::parse(l.trim()).ok();
        let is_frame =
            v.as_ref().map(|v| v.get("event").is_some()).unwrap_or(false);
        if !is_frame && frames == 0 {
            // a drain beat our stats poll: pick another replica instead
            // of surfacing the refusal to the client
            let drained = v
                .as_ref()
                .map(|v| {
                    v.get("draining").and_then(Json::as_bool) == Some(true)
                        && v.get("error").is_some()
                })
                .unwrap_or(false);
            if drained {
                return Ok(ForwardResult::Drained);
            }
        }
        // raw bytes through: the client sees exactly the replica's line
        client.write_all(l.as_bytes())?;
        if is_frame {
            frames += 1;
        } else {
            return Ok(ForwardResult::Done);
        }
    }
}

/// Route one generation request: assign the global id, pick a replica,
/// forward, and retry on a survivor while nothing has reached the
/// client yet.
fn route_request(rt: &Arc<Router>, v: Json, client: &mut TcpStream) -> Result<()> {
    let id = rt.next_id.fetch_add(1, Ordering::Relaxed);
    rt.metrics.requests.fetch_add(1, Ordering::Relaxed);
    let mut v = v;
    if let Json::Obj(m) = &mut v {
        m.insert("id".to_string(), Json::num(id as f64));
    }
    let line = v.to_string();
    let timeout = Duration::from_millis(rt.cfg.forward_timeout_ms);
    let mut attempts = 0usize;
    loop {
        let views: Vec<ReplicaView> = rt
            .replicas
            .iter()
            .map(|r| ReplicaView {
                alive: r.is_alive(),
                draining: r.stats().draining,
                load: r.load(),
            })
            .collect();
        let picked = rt
            .policy
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .pick(&views);
        let Some(k) = picked else {
            writeln!(
                client,
                "{}",
                Json::obj(vec![
                    ("id", Json::num(id as f64)),
                    ("error", Json::str("no replica available")),
                ])
                .to_string()
            )?;
            return Ok(());
        };
        let rep = &rt.replicas[k];
        rep.inflight.fetch_add(1, Ordering::Relaxed);
        rep.forwarded.fetch_add(1, Ordering::Relaxed);
        rt.inflight
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .insert(id, k);
        let res = forward_once(&rep.addr, &line, client, timeout);
        rt.inflight
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .remove(&id);
        rep.inflight.fetch_sub(1, Ordering::Relaxed);
        match res? {
            ForwardResult::Done => return Ok(()),
            ForwardResult::Drained => {
                if attempts < rt.cfg.max_retries {
                    attempts += 1;
                    rt.metrics.retries.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                writeln!(
                    client,
                    "{}",
                    Json::obj(vec![
                        ("id", Json::num(id as f64)),
                        ("error", Json::str("all replicas draining")),
                    ])
                    .to_string()
                )?;
                return Ok(());
            }
            ForwardResult::Failed { frames } => {
                rep.mark_dead();
                if frames == 0 && attempts < rt.cfg.max_retries {
                    // nothing reached the client: safe to re-run on a
                    // survivor (generation is seed-deterministic)
                    attempts += 1;
                    rt.metrics.retries.fetch_add(1, Ordering::Relaxed);
                    crate::log_warn!(
                        "req {id}: replica {k} failed before replying; rerouting"
                    );
                    continue;
                }
                // mid-stream casualty (frames already delivered can't be
                // replayed without double delivery) or retry budget
                // spent: structured error out, never a hang
                rt.metrics.midstream_failures.fetch_add(1, Ordering::Relaxed);
                let msg = if frames == 0 {
                    "replica failed before replying; retries exhausted"
                } else {
                    "replica failed mid-stream"
                };
                writeln!(
                    client,
                    "{}",
                    Json::obj(vec![
                        ("id", Json::num(id as f64)),
                        ("error", Json::str(msg)),
                        ("replica", Json::num(k as f64)),
                        ("frames_delivered", Json::num(frames as f64)),
                    ])
                    .to_string()
                )?;
                return Ok(());
            }
        }
    }
}

fn handle_client(rt: Arc<Router>, stream: TcpStream) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        loop {
            match reader.read_until(b'\n', &mut buf) {
                Ok(0) => return Ok(()), // client closed
                Ok(_) => break,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if rt.shutdown.load(Ordering::Relaxed) {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e.into()),
            }
        }
        let line = String::from_utf8_lossy(&buf);
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let v = match Json::parse(trimmed) {
            Ok(v) => v,
            Err(e) => {
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![("error", Json::str(&format!("{e}")))]).to_string()
                )?;
                continue;
            }
        };
        if let Some(cmd) = v.get("cmd") {
            let Some(cmd) = cmd.as_str() else {
                writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![
                        ("error", Json::str("cmd must be a string")),
                        ("field", Json::str("cmd")),
                    ])
                    .to_string()
                )?;
                continue;
            };
            let timeout = Duration::from_secs(10);
            match cmd {
                "shutdown" => {
                    rt.shutdown.store(true, Ordering::Relaxed);
                    writeln!(
                        writer,
                        "{}",
                        Json::obj(vec![("ok", Json::Bool(true))]).to_string()
                    )?;
                    return Ok(());
                }
                "stats" => {
                    writeln!(writer, "{}", rt.stats_json().to_string())?;
                }
                "metrics" => {
                    let mut bodies = Vec::new();
                    for r in &rt.replicas {
                        if !r.is_alive() {
                            continue;
                        }
                        match query_text(&r.addr, r#"{"cmd":"metrics"}"#, timeout) {
                            Ok(text) => bodies.push((r.index, text)),
                            Err(_) => r.mark_dead(),
                        }
                    }
                    let page = metrics::render_fleet(&bodies, &rt.replicas, &rt.metrics);
                    writer.write_all(page.as_bytes())?;
                    writer.flush()?;
                }
                "cancel" => {
                    let id = match v.get("req").and_then(Json::as_i64) {
                        Some(n) if n >= 1 => n as u64,
                        _ => {
                            writeln!(
                                writer,
                                "{}",
                                Json::obj(vec![
                                    (
                                        "error",
                                        Json::str("cancel needs a positive integer req id"),
                                    ),
                                    ("field", Json::str("req")),
                                ])
                                .to_string()
                            )?;
                            continue;
                        }
                    };
                    let owner = rt
                        .inflight
                        .lock()
                        .unwrap_or_else(std::sync::PoisonError::into_inner)
                        .get(&id)
                        .copied();
                    match owner {
                        Some(k) => {
                            rt.metrics.cancels.fetch_add(1, Ordering::Relaxed);
                            let cancel_line =
                                format!("{{\"cmd\":\"cancel\",\"req\":{id}}}");
                            match query_line(&rt.replicas[k].addr, &cancel_line, timeout) {
                                Ok(reply) => writeln!(writer, "{reply}")?,
                                Err(_) => writeln!(
                                    writer,
                                    "{}",
                                    Json::obj(vec![
                                        ("ok", Json::Bool(false)),
                                        ("req", Json::num(id as f64)),
                                        ("error", Json::str("replica unreachable")),
                                    ])
                                    .to_string()
                                )?,
                            }
                        }
                        None => writeln!(
                            writer,
                            "{}",
                            Json::obj(vec![
                                ("ok", Json::Bool(false)),
                                ("req", Json::num(id as f64)),
                                ("was", Json::str("not_found")),
                            ])
                            .to_string()
                        )?,
                    }
                }
                "drain" => {
                    let mut drained = 0usize;
                    for r in &rt.replicas {
                        if !r.is_alive() {
                            continue;
                        }
                        if query_line(&r.addr, r#"{"cmd":"drain"}"#, timeout).is_ok() {
                            drained += 1;
                        }
                    }
                    writeln!(
                        writer,
                        "{}",
                        Json::obj(vec![
                            ("ok", Json::Bool(true)),
                            ("draining", Json::Bool(true)),
                            ("replicas_drained", Json::num(drained as f64)),
                        ])
                        .to_string()
                    )?;
                }
                other => {
                    writeln!(
                        writer,
                        "{}",
                        Json::obj(vec![
                            (
                                "error",
                                Json::str(&format!(
                                    "unknown cmd {other:?} (stats|metrics|cancel|drain|shutdown)"
                                )),
                            ),
                            ("field", Json::str("cmd")),
                        ])
                        .to_string()
                    )?;
                }
            }
            continue;
        }
        route_request(&rt, v, &mut writer)?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A scripted stand-in for a replica server: answers `stats` with a
    /// canned idle snapshot and any other line with a final response
    /// echoing the request's id — enough protocol for the router's
    /// poll, pick, and forward paths without booting an engine. With
    /// `drop_gen` it stays healthy to the poller but hangs up on every
    /// generation request — the deterministic way to exercise the
    /// retry path (a plain dead replica loses the race to the poller).
    fn fake_replica(replica_id: usize, drop_gen: bool) -> (String, Arc<AtomicBool>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        listener.set_nonblocking(true).unwrap();
        std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((conn, _)) => {
                        let stop3 = Arc::clone(&stop2);
                        std::thread::spawn(move || {
                            let _ = serve_fake(conn, replica_id, drop_gen, stop3);
                        });
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        (addr, stop)
    }

    fn serve_fake(
        conn: TcpStream,
        replica_id: usize,
        drop_gen: bool,
        stop: Arc<AtomicBool>,
    ) -> Result<()> {
        conn.set_read_timeout(Some(Duration::from_millis(100)))?;
        let mut reader = BufReader::new(conn.try_clone()?);
        let mut w = conn;
        loop {
            let mut l = String::new();
            match reader.read_line(&mut l) {
                Ok(0) => return Ok(()),
                Ok(_) => {}
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if stop.load(Ordering::Relaxed) {
                        return Ok(());
                    }
                    continue;
                }
                Err(e) => return Err(e.into()),
            }
            let v = Json::parse(l.trim()).map_err(|e| anyhow::anyhow!("{e}"))?;
            if v.get("cmd").and_then(Json::as_str) == Some("stats") {
                writeln!(
                    w,
                    "{{\"replica_id\":{replica_id},\"active\":0,\"queued\":0,\
                     \"draining\":false,\"uptime_ms\":1,\"requests_done\":0}}"
                )?;
            } else if drop_gen {
                return Ok(()); // hang up without a reply line
            } else {
                let id = v.get("id").and_then(Json::as_i64).unwrap_or(0);
                writeln!(w, "{{\"id\":{id},\"text\":\"ok-{replica_id}\",\"new_tokens\":1}}")?;
            }
        }
    }

    /// A "replica" that accepts connections and immediately drops them:
    /// every forward fails before any reply line.
    fn dead_replica() -> String {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                drop(conn);
            }
        });
        addr
    }

    fn start_router(addrs: Vec<String>) -> (Arc<Router>, String, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let cfg = RouterConfig { addr: addr.clone(), poll_ms: 50, ..Default::default() };
        let rt = Arc::new(Router::new(cfg, addrs, Box::new(policy::RoundRobin::new())));
        let rt2 = Arc::clone(&rt);
        let h = std::thread::spawn(move || {
            let _ = rt2.serve_on(listener);
        });
        (rt, addr, h)
    }

    fn ask(addr: &str, line: &str) -> String {
        query_line(addr, line, Duration::from_secs(5)).unwrap()
    }

    #[test]
    fn routes_and_retries_onto_survivor() {
        // the flaky replica answers the poller's stats but hangs up on
        // generation, so it stays routable until the forward fails —
        // the retry path runs deterministically
        let (flaky, stop_a) = fake_replica(3, true);
        let (good, stop_b) = fake_replica(7, false);
        let (rt, addr, h) = start_router(vec![flaky, good]);
        let reply = ask(&addr, r#"{"prompt":"hi","max_new":4}"#);
        assert!(reply.contains("ok-7"), "survivor answered: {reply}");
        assert!(reply.contains("\"id\":1"), "global id injected: {reply}");
        assert!(rt.metrics.retries.load(Ordering::Relaxed) >= 1, "reroute accounted");
        assert!(!rt.replicas[0].is_alive(), "failed replica marked dead");
        let stats = Json::parse(&ask(&addr, r#"{"cmd":"stats"}"#)).unwrap();
        assert_eq!(stats.get("router").and_then(Json::as_bool), Some(true));
        assert_eq!(stats.get("requests").and_then(Json::as_i64), Some(1));
        assert_eq!(stats.get("retries").and_then(Json::as_i64), Some(1));
        ask(&addr, r#"{"cmd":"shutdown"}"#);
        stop_a.store(true, Ordering::Relaxed);
        stop_b.store(true, Ordering::Relaxed);
        h.join().unwrap();
    }

    #[test]
    fn unknown_cmd_and_bad_cancel_are_structured() {
        let (good, stop) = fake_replica(1, false);
        let (_rt, addr, h) = start_router(vec![good]);
        let reply = Json::parse(&ask(&addr, r#"{"cmd":"bogus"}"#)).unwrap();
        assert_eq!(reply.get("field").and_then(Json::as_str), Some("cmd"));
        assert!(reply.get("error").and_then(Json::as_str).unwrap().contains("bogus"));
        let reply = Json::parse(&ask(&addr, r#"{"cmd":"cancel"}"#)).unwrap();
        assert_eq!(reply.get("field").and_then(Json::as_str), Some("req"));
        // cancel of an unknown id: definitive not_found, not an error
        let reply = Json::parse(&ask(&addr, r#"{"cmd":"cancel","req":99}"#)).unwrap();
        assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(reply.get("was").and_then(Json::as_str), Some("not_found"));
        ask(&addr, r#"{"cmd":"shutdown"}"#);
        stop.store(true, Ordering::Relaxed);
        h.join().unwrap();
    }

    #[test]
    fn all_replicas_down_is_a_structured_error() {
        let bad = dead_replica();
        let (_rt, addr, h) = start_router(vec![bad]);
        let reply = Json::parse(&ask(&addr, r#"{"prompt":"hi"}"#)).unwrap();
        let err = reply.get("error").and_then(Json::as_str).unwrap_or("");
        assert!(
            err.contains("no replica available") || err.contains("retries exhausted"),
            "structured failure, got {reply:?}"
        );
        ask(&addr, r#"{"cmd":"shutdown"}"#);
        h.join().unwrap();
    }
}
