//! Pluggable replica-selection policies for the router.
//!
//! A policy sees one [`ReplicaView`] per replica — liveness, drain
//! state, and a load figure combining the replica's last-polled
//! `active + queued` with the router's own in-flight count toward it —
//! and picks the index to forward the next request to. Dead and
//! draining replicas are never routable; when nothing is routable the
//! router answers the client with a structured "no replica available"
//! error instead of queueing unboundedly.

/// What a policy knows about one replica at pick time.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaView {
    pub alive: bool,
    pub draining: bool,
    /// last-polled `active + queued` plus the router's own in-flight
    /// forwards — the freshest load signal available without a
    /// per-request stats round-trip
    pub load: usize,
}

impl ReplicaView {
    fn routable(&self) -> bool {
        self.alive && !self.draining
    }
}

pub trait RoutePolicy: Send {
    fn name(&self) -> &'static str;
    /// Index of the replica to forward to, or `None` when no replica
    /// is routable.
    fn pick(&mut self, replicas: &[ReplicaView]) -> Option<usize>;
}

/// Rotate through routable replicas in order.
#[derive(Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl RoutePolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn pick(&mut self, replicas: &[ReplicaView]) -> Option<usize> {
        let n = replicas.len();
        for off in 0..n {
            let i = (self.next + off) % n;
            if replicas[i].routable() {
                self.next = (i + 1) % n;
                return Some(i);
            }
        }
        None
    }
}

/// Send each request to the routable replica with the lowest load,
/// breaking ties round-robin so equal replicas still share work.
#[derive(Default)]
pub struct LeastLoaded {
    next: usize,
}

impl LeastLoaded {
    pub fn new() -> LeastLoaded {
        LeastLoaded::default()
    }
}

impl RoutePolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn pick(&mut self, replicas: &[ReplicaView]) -> Option<usize> {
        let n = replicas.len();
        let mut best: Option<usize> = None;
        // scan from the rotation point so ties rotate instead of always
        // landing on the lowest index
        for off in 0..n {
            let i = (self.next + off) % n;
            if !replicas[i].routable() {
                continue;
            }
            match best {
                Some(b) if replicas[b].load <= replicas[i].load => {}
                _ => best = Some(i),
            }
        }
        if let Some(i) = best {
            self.next = (i + 1) % n;
        }
        best
    }
}

/// Policy by CLI name (`--policy rr|least-loaded`).
pub fn make_policy(name: &str) -> Option<Box<dyn RoutePolicy>> {
    match name {
        "rr" | "round-robin" => Some(Box::new(RoundRobin::new())),
        "least-loaded" | "ll" => Some(Box::new(LeastLoaded::new())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(alive: bool, draining: bool, load: usize) -> ReplicaView {
        ReplicaView { alive, draining, load }
    }

    #[test]
    fn round_robin_rotates_and_skips_dead() {
        let mut p = RoundRobin::new();
        let views = vec![view(true, false, 0), view(false, false, 0), view(true, false, 0)];
        assert_eq!(p.pick(&views), Some(0));
        assert_eq!(p.pick(&views), Some(2), "dead replica 1 is skipped");
        assert_eq!(p.pick(&views), Some(0));
        // all dead: nothing routable
        let dead = vec![view(false, false, 0); 3];
        assert_eq!(p.pick(&dead), None);
    }

    #[test]
    fn round_robin_skips_draining() {
        let mut p = RoundRobin::new();
        let views = vec![view(true, true, 0), view(true, false, 0)];
        assert_eq!(p.pick(&views), Some(1));
        assert_eq!(p.pick(&views), Some(1));
    }

    #[test]
    fn least_loaded_prefers_idle_replica() {
        let mut p = LeastLoaded::new();
        let views = vec![view(true, false, 5), view(true, false, 1), view(true, false, 3)];
        assert_eq!(p.pick(&views), Some(1));
        // dead replicas are never picked no matter their load
        let views = vec![view(false, false, 0), view(true, false, 9)];
        assert_eq!(p.pick(&views), Some(1));
    }

    #[test]
    fn least_loaded_breaks_ties_round_robin() {
        let mut p = LeastLoaded::new();
        let views = vec![view(true, false, 2), view(true, false, 2)];
        let a = p.pick(&views).unwrap();
        let b = p.pick(&views).unwrap();
        assert_ne!(a, b, "equal load alternates between replicas");
    }

    #[test]
    fn policies_resolve_by_name() {
        assert_eq!(make_policy("rr").unwrap().name(), "round-robin");
        assert_eq!(make_policy("least-loaded").unwrap().name(), "least-loaded");
        assert!(make_policy("nope").is_none());
    }
}
