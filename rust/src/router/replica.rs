//! One routed-to replica: address, liveness with exponential-backoff
//! probing, the last `stats` snapshot, and the blocking line-oriented
//! TCP helpers the router uses to talk to it.

// Router threads must degrade (mark a replica dead, answer the client
// with a structured error) rather than panic.
#![warn(clippy::unwrap_used, clippy::expect_used)]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// First retry delay after a replica is marked dead; doubles per failed
/// probe up to [`PROBE_BACKOFF_MAX`].
const PROBE_BACKOFF_MIN: Duration = Duration::from_millis(100);
const PROBE_BACKOFF_MAX: Duration = Duration::from_secs(5);

/// The slice of a replica's `stats` reply the router keeps.
#[derive(Debug, Clone, Default)]
pub struct ReplicaStats {
    pub replica_id: usize,
    pub active: usize,
    pub queued: usize,
    pub draining: bool,
    pub uptime_ms: u64,
    pub requests_done: u64,
}

struct ProbeState {
    next: Instant,
    backoff: Duration,
}

pub struct Replica {
    pub addr: String,
    /// router-side index; replicas also self-report `replica_id`
    pub index: usize,
    alive: AtomicBool,
    /// requests this router currently has forwarded to the replica
    pub inflight: AtomicUsize,
    /// total requests ever forwarded here (retries that land here count)
    pub forwarded: AtomicU64,
    /// times this replica was marked dead
    pub failures: AtomicU64,
    stats: Mutex<ReplicaStats>,
    probe: Mutex<ProbeState>,
}

impl Replica {
    pub fn new(addr: String, index: usize) -> Replica {
        Replica {
            addr,
            index,
            alive: AtomicBool::new(true),
            inflight: AtomicUsize::new(0),
            forwarded: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            stats: Mutex::new(ReplicaStats::default()),
            probe: Mutex::new(ProbeState {
                next: Instant::now(),
                backoff: PROBE_BACKOFF_MIN,
            }),
        }
    }

    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }

    pub fn stats(&self) -> ReplicaStats {
        self.stats
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .clone()
    }

    /// Load signal for the routing policy: last-polled engine work plus
    /// the router's own not-yet-answered forwards.
    pub fn load(&self) -> usize {
        let s = self.stats();
        s.active + s.queued + self.inflight.load(Ordering::Relaxed)
    }

    /// A failed forward or poll: stop routing here and schedule the
    /// next liveness probe, doubling the backoff each consecutive
    /// failure (capped).
    pub fn mark_dead(&self) {
        if self.alive.swap(false, Ordering::Relaxed) {
            self.failures.fetch_add(1, Ordering::Relaxed);
            crate::log_warn!("replica {} ({}) marked dead", self.index, self.addr);
        }
        let mut p = self.probe.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        p.next = Instant::now() + p.backoff;
        p.backoff = (p.backoff * 2).min(PROBE_BACKOFF_MAX);
    }

    fn mark_alive(&self) {
        if !self.alive.swap(true, Ordering::Relaxed) {
            crate::log_info!("replica {} ({}) back alive", self.index, self.addr);
        }
        let mut p = self.probe.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        p.backoff = PROBE_BACKOFF_MIN;
    }

    /// One health/stats round-trip, rate-limited by the probe backoff
    /// while the replica is dead. Called by the router's poll thread.
    pub fn poll(&self, timeout: Duration) {
        if !self.is_alive() {
            let due = {
                let p =
                    self.probe.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                Instant::now() >= p.next
            };
            if !due {
                return;
            }
        }
        match query_json(&self.addr, r#"{"cmd":"stats"}"#, timeout) {
            Ok(v) => {
                let us = |key: &str| v.get(key).and_then(Json::as_usize).unwrap_or(0);
                let snap = ReplicaStats {
                    replica_id: us("replica_id"),
                    active: us("active"),
                    queued: us("queued"),
                    draining: v.get("draining").and_then(Json::as_bool).unwrap_or(false),
                    uptime_ms: us("uptime_ms") as u64,
                    requests_done: us("requests_done") as u64,
                };
                *self.stats.lock().unwrap_or_else(std::sync::PoisonError::into_inner) =
                    snap;
                self.mark_alive();
            }
            Err(_) => self.mark_dead(),
        }
    }
}

/// One line-in, line-out query against a replica (stats, cancel,
/// drain, shutdown).
pub fn query_line(addr: &str, line: &str, timeout: Duration) -> Result<String> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(timeout))?;
    let mut w = stream.try_clone()?;
    writeln!(w, "{line}")?;
    let mut out = String::new();
    if BufReader::new(stream).read_line(&mut out)? == 0 {
        anyhow::bail!("{addr} closed before replying");
    }
    Ok(out.trim_end().to_string())
}

/// [`query_line`], parsed.
pub fn query_json(addr: &str, line: &str, timeout: Duration) -> Result<Json> {
    let out = query_line(addr, line, timeout)?;
    Json::parse(&out).map_err(|e| anyhow::anyhow!("bad reply from {addr}: {e}"))
}

/// Multi-line query (the Prometheus `metrics` command): accumulate
/// lines through the `# EOF` terminator, which stays in the output.
pub fn query_text(addr: &str, line: &str, timeout: Duration) -> Result<String> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(timeout))?;
    let mut w = stream.try_clone()?;
    writeln!(w, "{line}")?;
    let mut reader = BufReader::new(stream);
    let mut out = String::new();
    loop {
        let mut l = String::new();
        if reader.read_line(&mut l)? == 0 {
            anyhow::bail!("{addr} closed before the # EOF terminator");
        }
        let done = l.trim_end() == "# EOF";
        out.push_str(&l);
        if done {
            return Ok(out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dead_replica_backs_off_probing() {
        let r = Replica::new("127.0.0.1:1".into(), 0);
        assert!(r.is_alive());
        r.mark_dead();
        assert!(!r.is_alive());
        assert_eq!(r.failures.load(Ordering::Relaxed), 1);
        // repeated mark_dead doesn't double-count the failure
        r.mark_dead();
        assert_eq!(r.failures.load(Ordering::Relaxed), 1);
        let backoff = {
            let p = r.probe.lock().unwrap();
            p.backoff
        };
        assert!(backoff > PROBE_BACKOFF_MIN, "backoff doubled after failures");
        assert!(backoff <= PROBE_BACKOFF_MAX);
        r.mark_alive();
        assert!(r.is_alive());
        let p = r.probe.lock().unwrap();
        assert_eq!(p.backoff, PROBE_BACKOFF_MIN, "recovery resets the backoff");
    }

    #[test]
    fn load_combines_stats_and_inflight() {
        let r = Replica::new("127.0.0.1:1".into(), 0);
        {
            let mut s = r.stats.lock().unwrap();
            s.active = 2;
            s.queued = 3;
        }
        r.inflight.store(4, Ordering::Relaxed);
        assert_eq!(r.load(), 9);
    }

    #[test]
    fn poll_against_nothing_marks_dead() {
        // port 1 is never listening: the poll must fail fast and flip
        // the replica to dead instead of erroring out
        let r = Replica::new("127.0.0.1:1".into(), 0);
        r.poll(Duration::from_millis(50));
        assert!(!r.is_alive());
    }
}
