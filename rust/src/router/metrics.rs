//! Fleet-level metrics: the router's own counters plus the merge of
//! every replica's Prometheus exposition into one page.
//!
//! Each replica serves its exposition over `{"cmd":"metrics"}`; the
//! router fetches all of them, tags every sample with a
//! `replica="<index>"` label, groups samples under one `# HELP`/`# TYPE`
//! header per metric family, appends its own `fe_router_*` series, and
//! terminates with a single `# EOF` — so one scrape of the router sees
//! the whole fleet with per-replica resolution.

#![warn(clippy::unwrap_used, clippy::expect_used)]

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::replica::Replica;

/// Router-side counters, all monotone.
#[derive(Default)]
pub struct RouterMetrics {
    /// generation requests accepted (each assigned a global id)
    pub requests: AtomicU64,
    /// reroutes of not-yet-started requests after a replica failure
    pub retries: AtomicU64,
    /// requests that died mid-stream and were answered with a
    /// structured error (frames already delivered, so no retry)
    pub midstream_failures: AtomicU64,
    /// cancel verbs forwarded to a replica
    pub cancels: AtomicU64,
}

fn sample_with_replica(line: &str, replica: usize) -> String {
    // `name{labels} value` gains `replica=..,` inside the braces;
    // `name value` gains a fresh label set
    if let Some(open) = line.find('{') {
        format!("{}{{replica=\"{replica}\",{}", &line[..open], &line[open + 1..])
    } else if let Some(sp) = line.find(' ') {
        format!("{}{{replica=\"{replica}\"}}{}", &line[..sp], &line[sp..])
    } else {
        line.to_string()
    }
}

/// Metric family name of a sample line: everything before `{` or ` `,
/// with the histogram-suffix kept (so `x_bucket`, `x_sum`, `x_count`
/// group under their own sample runs but inherit `x`'s header slot).
fn sample_name(line: &str) -> &str {
    let end = line.find(|c| c == '{' || c == ' ').unwrap_or(line.len());
    &line[..end]
}

/// Family a `_bucket`/`_sum`/`_count` series belongs to.
fn family_of(name: &str) -> &str {
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(stem) = name.strip_suffix(suffix) {
            return stem;
        }
    }
    name
}

/// Merge per-replica expositions (`(replica index, body)` pairs, each
/// ending in `# EOF`) into one fleet page, without the terminator —
/// [`render_fleet`] appends the router's own series and the final
/// `# EOF`.
fn merge_expositions(bodies: &[(usize, String)]) -> String {
    // family -> (header lines, sample lines); insertion-ordered so the
    // merged page reads like a replica's own
    let mut order: Vec<String> = Vec::new();
    let mut headers: std::collections::HashMap<String, Vec<String>> = Default::default();
    let mut samples: std::collections::HashMap<String, Vec<String>> = Default::default();
    for (replica, body) in bodies {
        for line in body.lines() {
            if line == "# EOF" || line.is_empty() {
                continue;
            }
            if let Some(rest) =
                line.strip_prefix("# HELP ").or_else(|| line.strip_prefix("# TYPE "))
            {
                let name = rest.split(' ').next().unwrap_or("");
                let fam = family_of(name).to_string();
                let entry = headers.entry(fam.clone()).or_insert_with(|| {
                    order.push(fam.clone());
                    Vec::new()
                });
                // first replica's header wins; duplicates dropped
                if !entry.iter().any(|h| h == line) {
                    entry.push(line.to_string());
                }
            } else {
                let fam = family_of(sample_name(line)).to_string();
                if !headers.contains_key(&fam) {
                    headers.entry(fam.clone()).or_insert_with(|| {
                        order.push(fam.clone());
                        Vec::new()
                    });
                }
                samples
                    .entry(fam)
                    .or_default()
                    .push(sample_with_replica(line, *replica));
            }
        }
    }
    let mut out = String::new();
    for fam in &order {
        for h in headers.get(fam).into_iter().flatten() {
            let _ = writeln!(out, "{h}");
        }
        for s in samples.get(fam).into_iter().flatten() {
            let _ = writeln!(out, "{s}");
        }
    }
    out
}

/// The full fleet exposition: merged replica pages + `fe_router_*`
/// series, `# EOF`-terminated. `bodies` holds whatever replica pages
/// could be fetched (dead replicas contribute only their
/// `fe_router_replica_up 0` gauge).
pub fn render_fleet(
    bodies: &[(usize, String)],
    replicas: &[Arc<Replica>],
    m: &RouterMetrics,
) -> String {
    let mut out = merge_expositions(bodies);
    let counter = |out: &mut String, name: &str, help: &str, v: u64| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {v}");
    };
    counter(
        &mut out,
        "fe_router_requests_total",
        "generation requests accepted by the router",
        m.requests.load(Ordering::Relaxed),
    );
    counter(
        &mut out,
        "fe_router_retries_total",
        "requests rerouted to a survivor after a replica failure",
        m.retries.load(Ordering::Relaxed),
    );
    counter(
        &mut out,
        "fe_router_midstream_failures_total",
        "requests lost mid-stream and answered with a structured error",
        m.midstream_failures.load(Ordering::Relaxed),
    );
    counter(
        &mut out,
        "fe_router_cancels_total",
        "cancel verbs forwarded to replicas",
        m.cancels.load(Ordering::Relaxed),
    );
    let labeled = |out: &mut String, name: &str, kind: &str, help: &str| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} {kind}");
    };
    labeled(&mut out, "fe_router_replica_up", "gauge", "1 if the replica is routable");
    for r in replicas {
        let _ = writeln!(
            out,
            "fe_router_replica_up{{replica=\"{}\"}} {}",
            r.index,
            u8::from(r.is_alive())
        );
    }
    labeled(
        &mut out,
        "fe_router_replica_inflight",
        "gauge",
        "requests currently forwarded and unanswered",
    );
    for r in replicas {
        let _ = writeln!(
            out,
            "fe_router_replica_inflight{{replica=\"{}\"}} {}",
            r.index,
            r.inflight.load(Ordering::Relaxed)
        );
    }
    labeled(
        &mut out,
        "fe_router_forwarded_total",
        "counter",
        "requests ever forwarded to the replica",
    );
    for r in replicas {
        let _ = writeln!(
            out,
            "fe_router_forwarded_total{{replica=\"{}\"}} {}",
            r.index,
            r.forwarded.load(Ordering::Relaxed)
        );
    }
    labeled(
        &mut out,
        "fe_router_replica_failures_total",
        "counter",
        "times the replica was marked dead",
    );
    for r in replicas {
        let _ = writeln!(
            out,
            "fe_router_replica_failures_total{{replica=\"{}\"}} {}",
            r.index,
            r.failures.load(Ordering::Relaxed)
        );
    }
    out.push_str("# EOF\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const PAGE_A: &str = "\
# HELP fe_requests_done_total completed generations
# TYPE fe_requests_done_total counter
fe_requests_done_total 3
# HELP fe_phase_us engine section wall time
# TYPE fe_phase_us histogram
fe_phase_us_bucket{method=\"fasteagle\",le=\"+Inf\"} 1
fe_phase_us_count{method=\"fasteagle\"} 1
# EOF
";

    const PAGE_B: &str = "\
# HELP fe_requests_done_total completed generations
# TYPE fe_requests_done_total counter
fe_requests_done_total 5
# EOF
";

    #[test]
    fn merge_labels_samples_and_dedupes_headers() {
        let merged =
            merge_expositions(&[(0, PAGE_A.to_string()), (1, PAGE_B.to_string())]);
        assert_eq!(
            merged.matches("# HELP fe_requests_done_total").count(),
            1,
            "one header per family"
        );
        assert!(merged.contains("fe_requests_done_total{replica=\"0\"} 3"));
        assert!(merged.contains("fe_requests_done_total{replica=\"1\"} 5"));
        // existing labels keep their place after the injected one
        assert!(merged
            .contains("fe_phase_us_bucket{replica=\"0\",method=\"fasteagle\",le=\"+Inf\"} 1"));
        // histogram suffixes group under the family header
        assert!(merged.contains("fe_phase_us_count{replica=\"0\",method=\"fasteagle\"} 1"));
        assert!(!merged.contains("# EOF"), "terminator is render_fleet's job");
    }

    #[test]
    fn render_fleet_appends_router_series_and_terminator() {
        let replicas =
            vec![Arc::new(Replica::new("a:1".into(), 0)), Arc::new(Replica::new("b:2".into(), 1))];
        replicas[1].mark_dead();
        let m = RouterMetrics::default();
        m.requests.store(7, Ordering::Relaxed);
        m.retries.store(2, Ordering::Relaxed);
        let page = render_fleet(&[(0, PAGE_B.to_string())], &replicas, &m);
        assert!(page.ends_with("# EOF\n"));
        assert_eq!(page.matches("# EOF").count(), 1);
        assert!(page.contains("fe_router_requests_total 7"));
        assert!(page.contains("fe_router_retries_total 2"));
        assert!(page.contains("fe_router_replica_up{replica=\"0\"} 1"));
        assert!(page.contains("fe_router_replica_up{replica=\"1\"} 0"));
        assert!(page.contains("fe_router_forwarded_total{replica=\"0\"} 0"));
        assert!(page.contains("fe_router_replica_failures_total{replica=\"1\"} 1"));
    }
}
