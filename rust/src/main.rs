//! `fasteagle` — CLI for the FastEagle speculative-decoding serving
//! stack.
//!
//! Commands:
//!   generate   one-shot generation with any drafter
//!   serve      TCP JSON-lines API server over the continuous batcher
//!   route      multi-replica router over one or more serve processes
//!   batch      closed-workload run through the continuous batcher
//!   bench      regenerate paper tables/figures (table1|table2|table3|fig3|microbench|all)
//!   selfcheck  losslessness + stack sanity across all drafters
//!   fixture    emit the deterministic interpreter-backed artifact tree
//!   check      static HLO verification + engine-contract report
//!   trace      batched run with the flight recorder armed; writes
//!              Chrome trace-event JSON (chrome://tracing / Perfetto)
//!
//! Common flags: --artifacts DIR (default ./artifacts; env FE_ARTIFACTS),
//! --target NAME (default base), --drafter NAME (default fasteagle),
//! --backend pjrt|interpret (env FE_BACKEND), --temp F, --max-new N,
//! --seed N, --quick.

use std::rc::Rc;
use std::sync::Arc;

use anyhow::{Context, Result};

use fasteagle::backend::BackendKind;
use fasteagle::coordinator::{
    BatchConfig, BatchEngine, BatchMethod, PolicyKind, Request, Server, ServerConfig,
};
use fasteagle::draft::make_drafter;
use fasteagle::model::TargetModel;
use fasteagle::runtime::{ArtifactStore, Runtime};
use fasteagle::spec::{DraftConfig, Engine, GenConfig, PlannerKind};
use fasteagle::util::cli::Args;

const USAGE: &str = "\
fasteagle <command> [flags]

commands:
  generate   --prompt TEXT [--drafter D] [--target T] [--temp F] [--max-new N]
  serve      [--addr HOST:PORT] [--method vanilla|eagle3|fasteagle] [--target T]
             [--batch B] [--chain N] [--pool-blocks N] [--queue N]
             [--policy fcfs|spf|cache] [--prefill-chunk N] [--frame-queue N]
             [--replica-id N]   (fleet identity reported by {\"cmd\":\"stats\"})
             [--prefix-cache]   (radix prefix cache; per-request opt-out
             via \"cache\": false)
             [--trace]   (arm the flight recorder; dump via {\"cmd\":\"trace\"})
             lifecycle verbs over the wire: {\"cmd\":\"cancel\",\"req\":ID},
             {\"cmd\":\"drain\"} (finish in-flight then exit), \"deadline_ms\"
             per request
  route      --replicas HOST:PORT,HOST:PORT,... | --spawn N
             [--addr HOST:PORT] [--policy rr|least-loaded] [--poll-ms N]
             [--max-retries N] [--forward-timeout-ms N]
             multi-replica router: global request ids, retry-on-failure,
             fleet stats/metrics; --spawn boots N in-process replicas
             sharing one artifact tree (serve flags apply to them)
  batch      [--batch B] [--method vanilla|eagle3|fasteagle] [--requests N]
             [--policy fcfs|spf|cache] [--prefix-cache]
  trace      [--out FILE] [--batch B] [--requests N] [--max-new N]
             run a batched workload with tracing on, write Chrome trace JSON
  bench      table1|table2|table3|fig3|micro|microbench|serve|all [--quick]
             [--interp-threads N]   (interpreter worker pool for this run)
  selfcheck  [--target T]
  fixture    [--out DIR] [--seed N]   emit interpreter-runnable artifacts
  check      [--target T] [--chain N] [--json]   verify HLO artifacts +
             engine contract without opening a backend; exit 0 iff clean

draft-plan flags (generate/serve/batch; per-request \"draft\" overrides):
  --planner static|adaptive  --draft-depth N  --draft-top-k N
  --draft-budget N  --no-tree (alias for --draft-top-k 1)

flags: --artifacts DIR  --backend pjrt|interpret  --seed N  --quick
env:   FE_TRACE=1 arms the flight recorder for any command;
       FE_LOG=level[,module=level] filters logging (see README);
       FE_INTERP_THREADS=N sizes the interpreter worker pool (default 1);
       FE_INTERP_FUSE=0 disables elementwise fusion;
       FE_INTERP_OPT=0 falls back to the naive reference evaluator
       (all three are byte-identical to the defaults; speed only)";

/// Backend selection: `--backend` flag, else `FE_BACKEND`, else PJRT.
fn make_runtime(args: &Args) -> Result<Arc<Runtime>> {
    let rt = match args.get("backend") {
        Some(b) => Runtime::new(BackendKind::from_str(b)?)?,
        None => Runtime::from_env()?,
    };
    Ok(Arc::new(rt))
}

fn artifacts_dir(args: &Args) -> String {
    args.get("artifacts")
        .map(String::from)
        .or_else(|| std::env::var("FE_ARTIFACTS").ok())
        .unwrap_or_else(|| "artifacts".to_string())
}

fn open_store(args: &Args, rt: &Arc<Runtime>) -> Result<Rc<ArtifactStore>> {
    let root = artifacts_dir(args);
    let target = args.str_or("target", "base");
    Ok(Rc::new(ArtifactStore::open(
        Arc::clone(rt),
        format!("{root}/{target}").into(),
    )?))
}

/// Draft-structure knobs shared by generate/serve/batch. `--no-tree`
/// (the "w/o Constrained Tree" ablation) is an alias for
/// `--draft-top-k 1`; `--max-depth` is kept as an alias of
/// `--draft-depth` from the pre-plan CLI.
fn draft_config(args: &Args) -> Result<DraftConfig> {
    let planner = match args.get("planner") {
        None => None,
        Some(p) => Some(
            PlannerKind::from_name(p)
                .ok_or_else(|| anyhow::anyhow!("unknown planner {p:?} (static|adaptive)"))?,
        ),
    };
    let parse_knob = |key: &str| -> Result<Option<usize>> {
        let cap = fasteagle::spec::plan::MAX_DRAFT_KNOB;
        match args.get(key) {
            None => Ok(None),
            Some(v) => match v.parse::<usize>() {
                Ok(n) if (1..=cap).contains(&n) => Ok(Some(n)),
                _ => Err(anyhow::anyhow!("invalid --{key} {v:?} (integer in 1..={cap})")),
            },
        }
    };
    let depth = match parse_knob("draft-depth")? {
        Some(d) => Some(d),
        None => parse_knob("max-depth")?,
    };
    let mut top_k = parse_knob("draft-top-k")?;
    if args.bool_flag("no-tree") {
        top_k = Some(1);
    }
    Ok(DraftConfig { planner, depth, top_k, budget: parse_knob("draft-budget")? })
}

fn gen_config(args: &Args) -> Result<GenConfig> {
    Ok(GenConfig {
        temperature: args.f64_or("temp", 0.0) as f32,
        max_new_tokens: args.usize_or("max-new", 64),
        seed: args.usize_or("seed", 0) as u64,
        draft: draft_config(args)?,
        stop_on_eos: args.bool_flag("stop-on-eos"),
    })
}

fn cmd_generate(args: &Args) -> Result<()> {
    let rt = make_runtime(args)?;
    let store = open_store(args, &rt)?;
    let target = TargetModel::open(Rc::clone(&store))?;
    let drafter = make_drafter(Rc::clone(&store), &args.str_or("drafter", "fasteagle"))?;
    let mut engine = Engine::new(target, drafter);
    let prompt = args
        .get("prompt")
        .context("--prompt required")?
        .to_string();
    let cfg = gen_config(args)?;
    let r = engine.generate(&prompt, &cfg)?;
    println!("{}", r.text);
    eprintln!(
        "--- {} tokens in {:.0}ms ({:.1} tok/s), tau={:.2}, cycles={}",
        r.metrics.new_tokens,
        r.metrics.wall.as_secs_f64() * 1e3,
        r.metrics.tokens_per_sec(),
        r.metrics.tau(),
        r.metrics.cycles,
    );
    eprintln!("{}", r.metrics.timer.report());
    Ok(())
}

fn batch_method(args: &Args) -> Result<BatchMethod> {
    // --method preferred; --drafter kept as an alias from the
    // single-engine serve days
    let name = args.str_or("method", &args.str_or("drafter", "fasteagle"));
    BatchMethod::from_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown batch method {name:?}"))
}

fn batch_config(args: &Args) -> Result<BatchConfig> {
    let mut cfg = BatchConfig::new(args.usize_or("batch", 1), batch_method(args)?);
    cfg.chain_len = args.usize_or("chain", 2);
    cfg.draft = draft_config(args)?;
    if let Some(v) = args.get("pool-blocks") {
        // a typo must not silently disable admission control
        let p: usize = v
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid --pool-blocks {v:?}"))?;
        cfg.pool_blocks = Some(p);
    }
    if let Some(p) = args.get("policy") {
        cfg.policy = PolicyKind::from_name(p)
            .ok_or_else(|| anyhow::anyhow!("unknown scheduling policy {p:?}"))?;
    }
    if let Some(c) = args.get("prefill-chunk") {
        cfg.prefill_chunk = c
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid --prefill-chunk {c:?}"))?;
    }
    cfg.prefix_cache = args.bool_flag("prefix-cache");
    Ok(cfg)
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.bool_flag("trace") {
        fasteagle::obs::enable();
    }
    let rt = make_runtime(args)?;
    let store = open_store(args, &rt)?;
    let engine = BatchEngine::new(Rc::clone(&store), batch_config(args)?)?;
    let server = Server::new(ServerConfig {
        addr: args.str_or("addr", "127.0.0.1:7399"),
        queue_capacity: args.usize_or("queue", 64),
        frame_queue: args.usize_or("frame-queue", 16),
        replica_id: args.usize_or("replica-id", 0),
    });
    // bind-in-use, KV leaks at drain exit, etc. exit with a message,
    // not a panic backtrace
    let metrics = match server.serve(engine) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("serve failed: {e:#}");
            std::process::exit(1);
        }
    };
    println!("server done: {}", metrics.report());
    Ok(())
}

/// `fasteagle route --spawn N`: boot N replica servers on OS-assigned
/// loopback ports, each with its own runtime + engine over the same
/// artifact tree (the PJRT buffer handles are deliberately
/// per-thread), and hand their addresses to the router.
fn spawn_replicas(
    args: &Args,
    n: usize,
) -> Result<(Vec<String>, Vec<std::thread::JoinHandle<Result<String>>>)> {
    let kind = match args.get("backend") {
        Some(b) => BackendKind::from_str(b)?,
        None => match std::env::var("FE_BACKEND") {
            Ok(v) if !v.is_empty() => BackendKind::from_str(&v)?,
            _ => BackendKind::Pjrt,
        },
    };
    let root = artifacts_dir(args);
    let target = args.str_or("target", "base");
    let dir = std::path::PathBuf::from(format!("{root}/{target}"));
    let cfg = batch_config(args)?;
    let queue_capacity = args.usize_or("queue", 64);
    let frame_queue = args.usize_or("frame-queue", 16);
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for i in 0..n {
        // bind in the parent so the address is known (and the port
        // race-free) before the router starts polling
        let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        addrs.push(addr.clone());
        let (dir, cfg) = (dir.clone(), cfg.clone());
        handles.push(std::thread::spawn(move || -> Result<String> {
            let rt = Arc::new(Runtime::new(kind)?);
            let store = Rc::new(ArtifactStore::open(rt, dir)?);
            let engine = BatchEngine::new(Rc::clone(&store), cfg)?;
            let server = Server::new(ServerConfig {
                addr,
                queue_capacity,
                frame_queue,
                replica_id: i + 1,
            });
            Ok(server.serve_on(listener, engine)?.report())
        }));
    }
    Ok((addrs, handles))
}

fn cmd_route(args: &Args) -> Result<()> {
    use fasteagle::router::{make_policy, query_line, Router, RouterConfig};

    let policy_name = args.str_or("policy", "least-loaded");
    let policy = make_policy(&policy_name)
        .ok_or_else(|| anyhow::anyhow!("unknown route policy {policy_name:?} (rr|least-loaded)"))?;
    let cfg = RouterConfig {
        addr: args.str_or("addr", "127.0.0.1:7400"),
        poll_ms: args.usize_or("poll-ms", 200) as u64,
        max_retries: args.usize_or("max-retries", 2),
        forward_timeout_ms: args.usize_or("forward-timeout-ms", 120_000) as u64,
    };
    let (addrs, spawned) = match args.get("spawn") {
        Some(v) => {
            let n: usize = v
                .parse()
                .map_err(|_| anyhow::anyhow!("--spawn must be a number, got {v:?}"))?;
            if !(1..=16).contains(&n) {
                anyhow::bail!("--spawn must be in 1..=16, got {n}");
            }
            spawn_replicas(args, n)?
        }
        None => {
            let list = args
                .get("replicas")
                .context("route needs --replicas HOST:PORT,HOST:PORT,... or --spawn N")?;
            let addrs: Vec<String> = list
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            if addrs.is_empty() {
                anyhow::bail!("--replicas has no addresses");
            }
            (addrs, Vec::new())
        }
    };
    let router = Arc::new(Router::new(cfg, addrs.clone(), policy));
    let served = router.serve();
    if !spawned.is_empty() {
        // the router is down; wind our own replicas down too (a dead
        // or already-exited replica just fails the connect)
        for addr in &addrs {
            let _ = query_line(addr, r#"{"cmd":"shutdown"}"#, std::time::Duration::from_secs(10));
        }
        for h in spawned {
            match h.join() {
                Ok(Ok(report)) => println!("replica done: {report}"),
                Ok(Err(e)) => eprintln!("replica failed: {e:#}"),
                Err(_) => eprintln!("replica thread panicked"),
            }
        }
    }
    match served {
        Ok(()) => Ok(()),
        Err(e) => {
            eprintln!("route failed: {e:#}");
            std::process::exit(1);
        }
    }
}

fn cmd_batch(args: &Args) -> Result<()> {
    let rt = make_runtime(args)?;
    let store = open_store(args, &rt)?;
    let mut engine = BatchEngine::new(Rc::clone(&store), batch_config(args)?)?;
    let root = artifacts_dir(args);
    let prompts =
        fasteagle::workload::load_prompts(std::path::Path::new(&root), "dialog")?;
    let n = args.usize_or("requests", 8);
    // generation parameters are per-request: each gets its own seed so
    // stochastic streams differ across the batch
    let base_seed = args.usize_or("seed", 0) as u64;
    let temp = args.f64_or("temp", 0.0) as f32;
    let reqs: Vec<Request> = (0..n)
        .map(|i| {
            let mut r = Request::new(i as u64, prompts[i % prompts.len()].clone());
            r.cfg.max_new_tokens = args.usize_or("max-new", 48);
            r.cfg.temperature = temp;
            r.cfg.seed = base_seed.wrapping_add(i as u64);
            r
        })
        .collect();
    let t0 = std::time::Instant::now();
    let (resps, m) = engine.run(reqs)?;
    let toks: usize = resps.iter().map(|r| r.new_tokens).sum();
    println!(
        "{} requests, {} tokens in {:.1}s -> {:.1} tok/s (tau={:.2}, occ={:.2}, deferred={})",
        resps.len(),
        toks,
        t0.elapsed().as_secs_f64(),
        toks as f64 / t0.elapsed().as_secs_f64(),
        m.mean_tau(),
        m.mean_occupancy(),
        m.requests_deferred,
    );
    Ok(())
}

/// `fasteagle trace` — drive a short closed batched workload with the
/// flight recorder armed and write the Chrome trace-event JSON to
/// `--out` (load it in chrome://tracing or <https://ui.perfetto.dev>).
fn cmd_trace(args: &Args) -> Result<()> {
    fasteagle::obs::enable();
    fasteagle::obs::reset();
    let rt = make_runtime(args)?;
    let store = open_store(args, &rt)?;
    let mut engine = BatchEngine::new(Rc::clone(&store), batch_config(args)?)?;
    let root = artifacts_dir(args);
    let prompts =
        fasteagle::workload::load_prompts(std::path::Path::new(&root), "dialog")?;
    let n = args.usize_or("requests", 4);
    let base_seed = args.usize_or("seed", 0) as u64;
    // ids start at 1: req 0 means "not request-scoped" in the trace
    let reqs: Vec<Request> = (0..n)
        .map(|i| {
            let mut r = Request::new(i as u64 + 1, prompts[i % prompts.len()].clone());
            r.cfg.max_new_tokens = args.usize_or("max-new", 24);
            r.cfg.seed = base_seed.wrapping_add(i as u64);
            r
        })
        .collect();
    let (resps, m) = engine.run(reqs)?;
    let events = fasteagle::obs::snapshot();
    let out = args.str_or("out", "trace.json");
    std::fs::write(&out, fasteagle::obs::chrome::trace_json(&events))
        .with_context(|| out.clone())?;
    println!(
        "{} requests, {} trace events -> {out} (load in chrome://tracing or ui.perfetto.dev)",
        resps.len(),
        events.len(),
    );
    println!("{}", m.report());
    Ok(())
}

fn cmd_selfcheck(args: &Args) -> Result<()> {
    let rt = make_runtime(args)?;
    let root = artifacts_dir(args);
    let target_name = args.str_or("target", "base");
    let dir: std::path::PathBuf = format!("{root}/{target_name}").into();
    let prompt = "USER: tell me about machine learning and the fast cache.\nASSISTANT:";
    let cfg = GenConfig { max_new_tokens: 32, ..Default::default() };

    let store = Rc::new(ArtifactStore::open(Arc::clone(&rt), dir.clone())?);
    let target = TargetModel::open(Rc::clone(&store))?;
    let spec = target.spec.clone();
    let mut vanilla_engine =
        Engine::new(target, make_drafter(Rc::clone(&store), "vanilla")?);
    let reference = vanilla_engine.generate(prompt, &cfg)?;
    println!("vanilla: {:?}", reference.text);
    let mut ok = true;
    let mut drafters = vec!["fasteagle".to_string(), "eagle3".to_string()];
    for extra in ["eagle2", "medusa", "sps", "fasteagle_par", "fasteagle_nofeat"] {
        if dir.join("weights").join(format!("{extra}.few")).exists() {
            drafters.push(extra.to_string());
        }
    }
    for dn in &drafters {
        let target = TargetModel::open(Rc::clone(&store))?;
        let mut engine = Engine::new(target, make_drafter(Rc::clone(&store), dn)?);
        let r = engine.generate(prompt, &cfg)?;
        let lossless = r.tokens == reference.tokens;
        ok &= lossless;
        println!(
            "{dn:>18}: tau={:.2} tok/s={:>6.1} lossless={}",
            r.metrics.tau(),
            r.metrics.tokens_per_sec(),
            if lossless { "YES" } else { "NO <-- MISMATCH" },
        );
    }
    println!(
        "selfcheck {} on target {} ({}, d={})",
        if ok { "PASSED" } else { "FAILED" },
        spec.name,
        spec.stands_for,
        spec.d_model,
    );
    if !ok {
        std::process::exit(1);
    }
    Ok(())
}

/// Emit the deterministic interpreter-backed artifact tree (tiny target
/// + cascaded drafter + EAGLE baseline) — the no-PJRT path to a running
/// draft→verify pipeline.
fn cmd_fixture(args: &Args) -> Result<()> {
    let out = args.str_or("out", "fixture_artifacts");
    let seed = args.usize_or("seed", 0) as u64;
    fasteagle::backend::fixture::generate_tree(std::path::Path::new(&out), seed)?;
    println!("fixture artifact tree (seed {seed}) -> {out}");
    println!("try: fasteagle selfcheck --backend interpret --artifacts {out}");
    Ok(())
}

/// `fasteagle check` — static verification of an artifact directory:
/// the HLO verifier over every `hlo/*.hlo.txt` (+ its `.io.json`
/// manifest), the per-executable state-tensor cross-checks, and the
/// engine-contract report for the B=1 lane and every lowered batch
/// lane. Pure file reads — no backend is opened, so it runs anywhere
/// the artifacts do. Exit code 0 iff no error-severity finding.
fn cmd_check(args: &Args) -> Result<()> {
    use std::collections::HashSet;

    use fasteagle::backend::hlo::parser::parse_module;
    use fasteagle::backend::hlo::verify::{self, Severity};
    use fasteagle::runtime::{contract, ExecManifest};
    use fasteagle::util::json::Json;

    let root = artifacts_dir(args);
    let target = args.str_or("target", "base");
    let dir = std::path::PathBuf::from(format!("{root}/{target}"));
    let spec_path = dir.join("spec.json");
    let spec_text = std::fs::read_to_string(&spec_path)
        .with_context(|| format!("read {}", spec_path.display()))?;
    let spec = fasteagle::model::ModelSpec::parse(&spec_text)?;

    let mut errors = 0usize;
    let mut warnings = 0usize;
    let mut human: Vec<String> = Vec::new();
    let mut json_rows: Vec<Json> = Vec::new();
    // the spec-level checks overlap (tree-nodes drift is reported by
    // every contract entry point) — dedupe identical findings
    let mut seen: HashSet<String> = HashSet::new();
    let mut record = |file: &str, sev: Severity, rule: &str, loc: &str, msg: &str| {
        if !seen.insert(format!("{file}|{rule}|{loc}|{msg}")) {
            return;
        }
        let sev_s = match sev {
            Severity::Error => {
                errors += 1;
                "error"
            }
            Severity::Warning => {
                warnings += 1;
                "warning"
            }
        };
        human.push(if loc.is_empty() {
            format!("{file}: {sev_s}[{rule}] {msg}")
        } else {
            format!("{file}: {sev_s}[{rule}] {loc}: {msg}")
        });
        json_rows.push(Json::obj(vec![
            ("file", Json::str(file)),
            ("severity", Json::str(sev_s)),
            ("rule", Json::str(rule)),
            ("where", Json::str(loc)),
            ("message", Json::str(msg)),
        ]));
    };

    // Layer 1: HLO verifier + manifest cross-check per executable
    let hlo_dir = dir.join("hlo");
    let mut names: Vec<String> = Vec::new();
    if hlo_dir.is_dir() {
        for entry in std::fs::read_dir(&hlo_dir)? {
            let p = entry?.path();
            let Some(fname) = p.file_name().and_then(|s| s.to_str()) else { continue };
            if let Some(name) = fname.strip_suffix(".hlo.txt") {
                names.push(name.to_string());
            }
        }
    }
    names.sort_unstable();
    for name in &names {
        let file = format!("hlo/{name}.hlo.txt");
        let text = std::fs::read_to_string(hlo_dir.join(format!("{name}.hlo.txt")))
            .with_context(|| file.clone())?;
        let module = match parse_module(&text) {
            Ok(m) => m,
            Err(e) => {
                record(&file, Severity::Error, "parse", "", &format!("{e:#}"));
                continue;
            }
        };
        let mut diags = verify::verify_module(&module);
        let io_path = hlo_dir.join(format!("{name}.io.json"));
        match std::fs::read_to_string(&io_path) {
            Ok(io_text) => match ExecManifest::parse(&io_text) {
                Ok(manifest) => {
                    diags.extend(verify::verify_manifest(&module, &manifest));
                    for i in contract::check_manifest_states(&spec, &manifest).issues {
                        record(&file, i.severity, i.rule, "", &i.message);
                    }
                }
                Err(e) => {
                    record(&file, Severity::Error, "manifest/parse", "", &format!("{e:#}"));
                }
            },
            Err(e) => record(
                &file,
                Severity::Error,
                "manifest/missing",
                "",
                &format!("{}: {e}", io_path.display()),
            ),
        }
        for d in diags {
            let loc = if d.instruction.is_empty() {
                d.computation.clone()
            } else {
                format!("{}/%{}", d.computation, d.instruction)
            };
            record(&file, d.severity, d.rule, &loc, &d.message);
        }
    }

    // Layer 2: engine contract — B=1 planners + every lowered batch lane
    let chain = args.usize_or("chain", 2);
    let block_slots = args.usize_or("block-slots", 16);
    let mut report = contract::check_single(&spec);
    report.merge(contract::check_engine(&spec, 1, chain));
    report.merge(contract::check_cache(&spec, block_slots, 1));
    for &b in &spec.batch_sizes {
        report.merge(contract::check_engine(&spec, b, chain));
        report.merge(contract::check_cache(&spec, block_slots, b));
    }
    report.merge(contract::check_inventory(&spec, &dir));
    for i in report.issues {
        record("spec.json", i.severity, i.rule, "", &i.message);
    }

    if args.bool_flag("json") {
        let j = Json::obj(vec![
            ("target", Json::str(&target)),
            ("errors", Json::num(errors as f64)),
            ("warnings", Json::num(warnings as f64)),
            ("diagnostics", Json::Arr(json_rows)),
        ]);
        println!("{}", j.to_string());
    } else {
        for line in &human {
            println!("{line}");
        }
        println!(
            "check {}: {} executable(s), {} error(s), {} warning(s) in {}",
            if errors == 0 { "clean" } else { "FAILED" },
            names.len(),
            errors,
            warnings,
            dir.display()
        );
    }
    if errors > 0 {
        std::process::exit(1);
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    // FE_TRACE=1 arms the flight recorder for any command (`serve
    // --trace` and the `trace` command arm it themselves)
    if matches!(std::env::var("FE_TRACE").ok().as_deref(), Some("1") | Some("true")) {
        fasteagle::obs::enable();
    }
    let Some(cmd) = args.positional.first().map(String::as_str) else {
        println!("{USAGE}");
        return Ok(());
    };
    match cmd {
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "route" => cmd_route(&args),
        "batch" => cmd_batch(&args),
        "bench" => {
            let which = args
                .positional
                .get(1)
                .map(String::as_str)
                .unwrap_or("all");
            std::env::set_var("FE_ARTIFACTS", artifacts_dir(&args));
            if let Some(t) = args.get("interp-threads") {
                let n: usize = t
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--interp-threads must be a number, got {t:?}"))?;
                if n == 0 || n > 64 {
                    anyhow::bail!("--interp-threads must be in 1..=64, got {n}");
                }
                // EvalOptions::from_env reads this when the interpreter
                // backend compiles its execution plans
                std::env::set_var("FE_INTERP_THREADS", t);
            }
            // BenchEnv reads the backend from the env (`--backend
            // interpret` is the everywhere-runnable lane)
            fasteagle::bench::export_backend(&args)?;
            fasteagle::bench::run_named(which, args.bool_flag("quick"))
        }
        "selfcheck" => cmd_selfcheck(&args),
        "fixture" => cmd_fixture(&args),
        "check" => cmd_check(&args),
        "trace" => cmd_trace(&args),
        other => {
            println!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    }
}
