"""Drafter architectures (L2): the FastEagle cascade (paper §2.1) and the
baselines it is compared against (EAGLE-3-like, EAGLE-2-like, Medusa, SpS).

Conventions shared with the Rust coordinator (L3):

* A drafter "anchor" is a verified token position t whose target features
  feed the drafter. Per generation cycle the coordinator (a) appends one
  permanent context entry per newly-accepted token — built from *real*
  verified features, EAGLE-3's design philosophy — and (b) runs the draft
  itself with temporary entries that are rolled back after verification.
* ``fe_apply`` is the paper's cascaded drafter: one forward through N
  structurally-cascaded decoder layers emits all N distributions
  (eqs. 1–2). ``parallel=True`` is the "w/o Cascaded Structure" ablation
  (independent heads, h_i = L_i(x0)).
* All drafter logits go through the frozen target LM head (the ``emb``
  tensor is a frozen copy of the target's tied embedding, excluded from
  the optimizer in train.py), as in the paper.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .configs import DRAFT_DEPTH, MEDUSA_HEADS, TargetConfig
from .layers import block_apply, init_block, rmsnorm


def _gelu(h):
    return 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h**3)))


# ----------------------------------------------------------------------------
# FastEagle cascade
# ----------------------------------------------------------------------------

def init_fasteagle(key, cfg: TargetConfig, target_emb: jnp.ndarray,
                   n_cascade: int = DRAFT_DEPTH) -> Dict:
    d = cfg.d_model
    ks = jax.random.split(key, n_cascade + 3)
    return {
        "emb": target_emb,  # frozen (token embedding + LM head)
        "pos": jax.random.normal(ks[0], (cfg.max_seq, d), jnp.float32) * 0.02,
        "fc3_w": jax.random.normal(ks[1], (3 * d, d), jnp.float32) * 0.02,
        "fc3_b": jnp.zeros((d,), jnp.float32),
        "fcin_w": jax.random.normal(ks[2], (2 * d, d), jnp.float32) * 0.02,
        "fcin_b": jnp.zeros((d,), jnp.float32),
        "blocks": {
            str(i): init_block(ks[3 + i], d, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, cfg.ffn, n_cascade)
            for i in range(n_cascade)
        },
        "ln_h": {str(i): jnp.ones((d,), jnp.float32) for i in range(n_cascade)},
    }


def fe_kv_shape(cfg: TargetConfig, batch: int, c: int | None = None,
                n_cascade: int = DRAFT_DEPTH) -> Tuple[int, ...]:
    c = c or cfg.max_seq
    return (n_cascade, 2, batch, c, cfg.n_kv_heads, cfg.head_dim)


def fe_apply(
    params: Dict,
    feats: jnp.ndarray,  # [B, T, 3d] target tap features of the anchors
    next_tokens: jnp.ndarray,  # [B, T] i32 — e_{t+1} per anchor (eq. 1)
    anchor_pos: jnp.ndarray,  # [B, T] i32 token positions of the anchors
    mask: jnp.ndarray,  # [B, T, C] additive over the drafter context
    ctx_len: jnp.ndarray,  # [B] i32 — per-request slot for the T new entries
    dkv: jnp.ndarray,  # [N, 2, B, C, KH, hd]
    *,
    cfg: TargetConfig,
    n_cascade: int = DRAFT_DEPTH,
    parallel: bool = False,
    use_pallas: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-pass cascade. Returns (logits [B,T,N,V], hidden [B,T,N,d], dkv').

    Layer i's logits row is the draft distribution q_{t+i} (paper eq. 2):
    shallow layers handle short-range, deep layers long-range positions.
    """
    g = feats @ params["fc3_w"] + params["fc3_b"]
    e = params["emb"][next_tokens]
    x0 = jnp.concatenate([g, e], axis=-1) @ params["fcin_w"] + params["fcin_b"]
    x0 = x0 + params["pos"][anchor_pos]
    x = x0
    hidden = []
    new_kv = []
    for i in range(n_cascade):
        inp = x0 if parallel else x
        x, kc, vc = block_apply(
            params["blocks"][str(i)], inp, dkv[i, 0], dkv[i, 1], mask, ctx_len,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, use_pallas=use_pallas,
        )
        new_kv.append(jnp.stack([kc, vc]))
        hidden.append(x)
    hs = jnp.stack(hidden, axis=2)  # [B, T, N, d]
    normed = jnp.stack(
        [rmsnorm(hs[:, :, i], params["ln_h"][str(i)]) for i in range(n_cascade)],
        axis=2,
    )
    logits = normed @ params["emb"].T  # frozen LM head
    return logits, hs, jnp.stack(new_kv)


# ----------------------------------------------------------------------------
# EAGLE (autoregressive single-layer drafter; -3-like and -2-like variants)
# ----------------------------------------------------------------------------

def init_eagle(key, cfg: TargetConfig, target_emb: jnp.ndarray,
               multi_level: bool = True) -> Dict:
    d = cfg.d_model
    fin = 3 * d if multi_level else d
    ks = jax.random.split(key, 5)
    return {
        "emb": target_emb,  # frozen
        "pos": jax.random.normal(ks[0], (cfg.max_seq, d), jnp.float32) * 0.02,
        "fc3_w": jax.random.normal(ks[1], (fin, d), jnp.float32) * 0.02,
        "fc3_b": jnp.zeros((d,), jnp.float32),
        "fch_w": jax.random.normal(ks[2], (d, d), jnp.float32) * 0.02,
        "fch_b": jnp.zeros((d,), jnp.float32),
        "fcin_w": jax.random.normal(ks[3], (2 * d, d), jnp.float32) * 0.02,
        "fcin_b": jnp.zeros((d,), jnp.float32),
        "block": init_block(ks[4], d, cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim, cfg.ffn, 1),
        "ln_h": jnp.ones((d,), jnp.float32),
    }


def eg_kv_shape(cfg: TargetConfig, batch: int, c: int | None = None) -> Tuple[int, ...]:
    c = c or cfg.max_seq
    return (2, batch, c, cfg.n_kv_heads, cfg.head_dim)


def eg_apply(
    params: Dict,
    feat_in: jnp.ndarray,  # [B, T, 3d|d] (first) or [B, T, d] (own hidden)
    tokens: jnp.ndarray,  # [B, T] i32
    anchor_pos: jnp.ndarray,  # [B, T] i32
    mask: jnp.ndarray,  # [B, T, C]
    ctx_len: jnp.ndarray,  # [B] i32
    ekv: jnp.ndarray,  # [2, B, C, KH, hd]
    *,
    cfg: TargetConfig,
    first: bool,
    use_pallas: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One EAGLE step. Drafting a depth-N chain takes N sequential calls —
    exactly the latency bottleneck FastEagle removes. Returns
    (logits [B,T,V], h [B,T,d], ekv')."""
    if first:
        g = feat_in @ params["fc3_w"] + params["fc3_b"]
    else:
        g = feat_in @ params["fch_w"] + params["fch_b"]
    e = params["emb"][tokens]
    x = jnp.concatenate([g, e], axis=-1) @ params["fcin_w"] + params["fcin_b"]
    x = x + params["pos"][anchor_pos]
    x, kc, vc = block_apply(
        params["block"], x, ekv[0], ekv[1], mask, ctx_len,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, use_pallas=use_pallas,
    )
    logits = rmsnorm(x, params["ln_h"]) @ params["emb"].T
    return logits, x, jnp.stack([kc, vc])


# ----------------------------------------------------------------------------
# Medusa (stateless parallel heads off the anchor feature)
# ----------------------------------------------------------------------------

def init_medusa(key, cfg: TargetConfig, target_emb: jnp.ndarray,
                n_heads: int = MEDUSA_HEADS) -> Dict:
    d = cfg.d_model
    ks = jax.random.split(key, n_heads + 1)
    return {
        "emb": target_emb,  # frozen
        "fc3_w": jax.random.normal(ks[0], (3 * d, d), jnp.float32) * 0.02,
        "fc3_b": jnp.zeros((d,), jnp.float32),
        "heads": {
            str(i): {
                "wa": jax.random.normal(ks[1 + i], (d, d), jnp.float32) * 0.02,
                "ba": jnp.zeros((d,), jnp.float32),
            }
            for i in range(n_heads)
        },
        "ln_h": jnp.ones((d,), jnp.float32),
    }


def medusa_apply(
    params: Dict,
    feats: jnp.ndarray,  # [B, T, 3d]
    *,
    n_heads: int = MEDUSA_HEADS,
) -> jnp.ndarray:  # [B, T, K, V]
    z = _gelu(feats @ params["fc3_w"] + params["fc3_b"])
    outs = []
    for i in range(n_heads):
        h = params["heads"][str(i)]
        r = z + _gelu(z @ h["wa"] + h["ba"])
        outs.append(rmsnorm(r, params["ln_h"]) @ params["emb"].T)
    return jnp.stack(outs, axis=2)
