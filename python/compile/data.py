"""Synthetic 5-task byte-level corpus ("SynthTasks suite").

Stands in for the paper's training data (ShareGPT / UltraChat /
OpenThoughts-math) and evaluation suites (MT-Bench, HumanEval, GSM8K,
Alpaca, CNN/DM) — see DESIGN.md §Substitutions. The generators are
template grammars with per-task vocabulary-pool sizes chosen so that the
*predictability ordering* matches the paper's acceptance-rate ordering:
``code`` is the most templated (highest acceptance / speedup) and ``news``
the most diverse (lowest), with dialog/math/inst in between.

Everything is seeded and deterministic; prompts exported for the Rust
side come from the same grammars (held-out seeds).
"""

from __future__ import annotations

import random
from typing import List, Tuple

from .configs import TASKS

# ----------------------------------------------------------------------------
# word pools
# ----------------------------------------------------------------------------

_NOUNS = [
    "cache", "server", "garden", "river", "engine", "market", "ticket",
    "window", "signal", "packet", "bridge", "forest", "teacher", "student",
    "laptop", "recipe", "battery", "journey", "library", "harbor",
]
_ADJ = [
    "fast", "green", "quiet", "bright", "heavy", "simple", "robust",
    "gentle", "narrow", "steady", "golden", "hidden",
]
_VERBS = [
    "build", "update", "carry", "measure", "review", "restart", "deliver",
    "explain", "improve", "collect", "balance", "observe",
]
_TOPICS = [
    "the weather", "a good book", "machine learning", "a travel plan",
    "healthy food", "music practice", "home repair", "city transport",
]
_FUNCS = ["add", "scale", "merge", "clip", "norm", "pack", "split", "rank"]
_ITEMS = ["apples", "pencils", "tickets", "coins", "books", "stickers"]
_NAMES = ["Ana", "Ben", "Cara", "Dan", "Eve", "Finn", "Gia", "Hugo"]
_NEWS_SUBJ = [
    "the city council", "a research team", "the local museum",
    "the transit agency", "a startup", "the weather service",
    "the harbor authority", "a volunteer group", "the school board",
    "an engineering firm", "the national library", "a farming cooperative",
]
_NEWS_ACT = [
    "announced a new plan", "released its annual report",
    "opened a public exhibit", "completed a major upgrade",
    "launched a pilot program", "published updated guidance",
    "approved additional funding", "restored an old landmark",
    "expanded its services", "presented early results",
]
_NEWS_TAIL = [
    "officials said on Monday", "according to a statement",
    "residents welcomed the change", "details remain limited",
    "the effort took several months", "more updates are expected soon",
    "critics asked for more data", "the budget was not disclosed",
]


def _w(rng: random.Random, pool: List[str]) -> str:
    return pool[rng.randrange(len(pool))]


# ----------------------------------------------------------------------------
# per-task generators: each returns (prompt, response) strings
# ----------------------------------------------------------------------------

def gen_dialog(rng: random.Random) -> Tuple[str, str]:
    """MT-Bench stand-in: two-turn assistant dialogue, template answers."""
    topic = _w(rng, _TOPICS)
    adj = _w(rng, _ADJ)
    noun = _w(rng, _NOUNS)
    prompt = f"USER: tell me about {topic} and the {adj} {noun}.\nASSISTANT:"
    resp = (
        f" sure. {topic} is a common subject. the {adj} {noun} matters"
        f" because the {noun} is {adj} and useful. in short, {topic} and"
        f" the {adj} {noun} go well together.\n"
    )
    return prompt, resp


def gen_code(rng: random.Random) -> Tuple[str, str]:
    """HumanEval stand-in: tiny python-like function bodies, very templated."""
    f = _w(rng, _FUNCS)
    a, b = "x", "y"
    k = rng.randrange(2, 9)
    prompt = f"# task: implement {f}\ndef {f}({a}, {b}):\n"
    body = (
        f"    total = {a} + {b}\n"
        f"    for i in range({k}):\n"
        f"        total = total + i\n"
        f"    return total\n"
    )
    return prompt, body


def gen_math(rng: random.Random) -> Tuple[str, str]:
    """GSM8K stand-in: one-step word arithmetic with a worked answer."""
    name = _w(rng, _NAMES)
    item = _w(rng, _ITEMS)
    n1 = rng.randrange(2, 60)
    n2 = rng.randrange(2, 60)
    s = n1 + n2
    prompt = (
        f"Q: {name} has {n1} {item} and buys {n2} more {item}."
        f" how many {item} does {name} have?\nA:"
    )
    resp = f" {name} has {n1} + {n2} = {s} {item}. the answer is {s}.\n"
    return prompt, resp


def gen_inst(rng: random.Random) -> Tuple[str, str]:
    """Alpaca stand-in: instruction -> response templates."""
    verb = _w(rng, _VERBS)
    noun = _w(rng, _NOUNS)
    adj = _w(rng, _ADJ)
    prompt = f"### Instruction: {verb} the {adj} {noun}.\n### Response:"
    resp = (
        f" to {verb} the {adj} {noun}, first inspect the {noun}, then"
        f" {verb} it carefully until the {noun} is {adj}. done.\n"
    )
    return prompt, resp


def gen_news(rng: random.Random) -> Tuple[str, str]:
    """CNN/DM stand-in: multi-sentence article + TL;DR (most diverse)."""
    sents = []
    for _ in range(rng.randrange(2, 4)):
        sents.append(
            f"{_w(rng, _NEWS_SUBJ)} {_w(rng, _NEWS_ACT)}, {_w(rng, _NEWS_TAIL)}."
        )
    subj = _w(rng, _NEWS_SUBJ)
    act = _w(rng, _NEWS_ACT)
    prompt = " ".join(sents) + f" {subj} {act}. TL;DR:"
    resp = f" {subj} {act}, {_w(rng, _NEWS_TAIL)}.\n"
    return prompt, resp


_GENS = {
    "dialog": gen_dialog,
    "code": gen_code,
    "math": gen_math,
    "inst": gen_inst,
    "news": gen_news,
}


def gen_example(task: str, rng: random.Random) -> Tuple[str, str]:
    return _GENS[task](rng)


def corpus(
    n_seqs: int,
    mixture: Tuple[float, ...],
    seed: int,
) -> List[str]:
    """Training corpus: prompt+response concatenations, task-mixed."""
    rng = random.Random(seed)
    total = sum(mixture)
    out: List[str] = []
    for _ in range(n_seqs):
        r = rng.random() * total
        acc = 0.0
        task = TASKS[-1]
        for t, w in zip(TASKS, mixture):
            acc += w
            if r <= acc:
                task = t
                break
        p, a = gen_example(task, rng)
        out.append(p + a)
    return out


def eval_prompts(task: str, n: int, seed: int = 10_000) -> List[str]:
    """Held-out prompts (prompt part only) for the Rust-side evaluation."""
    rng = random.Random(seed + hash(task) % 1000)
    return [gen_example(task, rng)[0] for _ in range(n)]


# ----------------------------------------------------------------------------
# byte-level tokenization (mirrored by rust/src/model/tokenizer.rs)
# ----------------------------------------------------------------------------

def encode(text: str) -> List[int]:
    return list(text.encode("utf-8", errors="replace"))


def decode(tokens: List[int]) -> str:
    return bytes(t for t in tokens if 0 <= t < 256).decode("utf-8", errors="replace")
