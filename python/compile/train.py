"""Training (build-time only): targets from scratch, drafters by
distillation with the paper's multi-level objective (§2.3).

    L_total = Σ_i w_i (α · CE_i + β · L_feat,i),   w_i = 0.9^{N-i}

* CE_i is soft cross-entropy between drafter layer i's distribution and
  the target's teacher distribution at the matching position (eq. 4).
* L_feat,i is Smooth-L1 between the cascade hidden h_i and the target's
  top-tap feature at the matching position (eqs. 5–6) — the anchoring
  that the "w/o Feature Loss" ablation removes.
* Training is end-to-end without teacher forcing across the cascade:
  layer i consumes h_{i-1} from the same forward pass (paper §2.3).

Optimizer: AdamW, (β1, β2) = (0.9, 0.95), grad-clip 0.5 (paper §3);
hand-rolled because optax is unavailable offline. The frozen LM-head /
embedding copy inside each drafter is masked out of the update.

The teacher pass is run once over the corpus and cached ("we call the
target model to generate responses": here the target is tiny enough that
we instead distill on teacher distributions over the corpus, which is the
same supervision signal at temperature 1).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .configs import (BOS, DRAFT_DEPTH, MEDUSA_HEADS, PAD, DrafterConfig,
                      TargetConfig, TrainConfig)
from .drafters import (eg_apply, eg_kv_shape, fe_apply, fe_kv_shape,
                       init_eagle, init_fasteagle, init_medusa, medusa_apply)
from .layers import causal_mask
from .model import init_target, target_train_apply

# ----------------------------------------------------------------------------
# data plumbing
# ----------------------------------------------------------------------------

def tokenize_corpus(texts: List[str], seq_len: int) -> np.ndarray:
    """[n, seq_len+1] i32: BOS + bytes, PAD-filled."""
    out = np.full((len(texts), seq_len + 1), PAD, dtype=np.int32)
    for i, t in enumerate(texts):
        toks = [BOS] + data_mod.encode(t)
        toks = toks[: seq_len + 1]
        out[i, : len(toks)] = toks
    return out


# ----------------------------------------------------------------------------
# AdamW (hand-rolled)
# ----------------------------------------------------------------------------

def adamw_init(params) -> Dict:
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l * l) for l in leaves))


def adamw_update(params, grads, state, *, lr: float, tc: TrainConfig,
                 frozen: Tuple[str, ...] = ()):
    """One AdamW step with global-norm clipping; top-level keys listed in
    ``frozen`` (e.g. the drafter's LM-head copy) are left untouched."""
    gn = _global_norm(grads)
    scale = jnp.minimum(1.0, tc.grad_clip / (gn + 1e-12))
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
    t = state["t"] + 1
    b1, b2 = tc.beta1, tc.beta2
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    bc1 = 1.0 - b1 ** tf
    bc2 = 1.0 - b2 ** tf

    def upd(p, m_, v_):
        step = lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + 1e-8)
        return p - step - lr * tc.weight_decay * p

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    # restore frozen top-level entries
    for k in frozen:
        new_params[k] = params[k]
        m[k] = state["m"][k]
        v[k] = state["v"][k]
    return new_params, {"m": m, "v": v, "t": t}


# ----------------------------------------------------------------------------
# losses
# ----------------------------------------------------------------------------

def soft_ce(student_logits: jnp.ndarray, teacher_logits: jnp.ndarray,
            valid: jnp.ndarray) -> jnp.ndarray:
    """Eq. 4: CE against the teacher distribution; masked mean."""
    p = jax.nn.softmax(teacher_logits, axis=-1)
    logq = jax.nn.log_softmax(student_logits, axis=-1)
    ce = -jnp.sum(p * logq, axis=-1)
    return jnp.sum(ce * valid) / (jnp.sum(valid) + 1e-6)


def smooth_l1(x: jnp.ndarray) -> jnp.ndarray:
    """Eq. 6."""
    ax = jnp.abs(x)
    return jnp.where(ax < 1.0, 0.5 * x * x, ax - 0.5)


def feat_loss(h: jnp.ndarray, f: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Eq. 5: Smooth-L1 between drafter hidden and target feature, masked
    mean over positions.

    Deviation from the paper (recorded in EXPERIMENTS.md §Deviations): we
    average over the feature dim instead of summing. Our from-scratch
    targets have feature magnitudes ~15 per dim, so the summed form
    (~3000 per position) drowns the CE term after global-norm clipping
    and *inverts* the Table-2 ablation; the mean form keeps the two terms
    on comparable scales, which is the regime the paper's (α, β) implies
    for unit-scale LLaMA features."""
    l = jnp.mean(smooth_l1(h - f), axis=-1)
    return jnp.sum(l * valid) / (jnp.sum(valid) + 1e-6)


# ----------------------------------------------------------------------------
# target training
# ----------------------------------------------------------------------------

def train_target(cfg: TargetConfig, tc: TrainConfig, tokens: np.ndarray,
                 log: Callable[[str], None]) -> Tuple[Dict, List[float]]:
    key = jax.random.PRNGKey(tc.seed)
    params = init_target(key, cfg)

    def loss_fn(p, batch):
        logits, _ = target_train_apply(p, batch[:, :-1], cfg=cfg)
        targets = batch[:, 1:]
        valid = (targets != PAD).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        return -jnp.sum(ll * valid) / (jnp.sum(valid) + 1e-6)

    opt = adamw_init(params)

    @jax.jit
    def step(p, o, batch):
        l, g = jax.value_and_grad(loss_fn)(p, batch)
        p, o = adamw_update(p, g, o, lr=tc.target_lr, tc=tc)
        return p, o, l

    rng = np.random.default_rng(tc.seed)
    losses = []
    t0 = time.time()
    for s in range(tc.target_steps):
        idx = rng.integers(0, tokens.shape[0], tc.batch)
        params, opt, l = step(params, opt, jnp.asarray(tokens[idx]))
        losses.append(float(l))
        if s % 100 == 0 or s == tc.target_steps - 1:
            log(f"  target[{cfg.name}] step {s} loss {float(l):.4f} "
                f"({time.time() - t0:.0f}s)")
    return params, losses


# ----------------------------------------------------------------------------
# teacher harvesting
# ----------------------------------------------------------------------------

def harvest(cfg: TargetConfig, params: Dict, tokens: np.ndarray,
            batch: int = 32) -> Tuple[np.ndarray, np.ndarray]:
    """Teacher pass over the corpus -> (logits [n,T,V], feats [n,T,3d])."""
    fn = jax.jit(lambda p, b: target_train_apply(p, b, cfg=cfg))
    outs_l, outs_f = [], []
    n = tokens.shape[0]
    for i in range(0, n, batch):
        b = jnp.asarray(tokens[i: i + batch, :-1])
        l, f = fn(params, b)
        outs_l.append(np.asarray(l, dtype=np.float32))
        outs_f.append(np.asarray(f, dtype=np.float32))
    return np.concatenate(outs_l), np.concatenate(outs_f)


# ----------------------------------------------------------------------------
# drafter training
# ----------------------------------------------------------------------------

def _layer_weights(n: int, decay: float) -> np.ndarray:
    return np.array([decay ** (n - i) for i in range(1, n + 1)], np.float32)


def train_fasteagle(cfg: TargetConfig, dc: DrafterConfig, tc: TrainConfig,
                    target_params: Dict, tokens: np.ndarray,
                    t_logits: np.ndarray, t_feats: np.ndarray,
                    log: Callable[[str], None]) -> Tuple[Dict, List[float]]:
    """FastEagle cascade training (also the _nofeat / _par ablations)."""
    n = DRAFT_DEPTH
    d = cfg.d_model
    key = jax.random.PRNGKey(tc.seed + 1)
    params = init_fasteagle(key, cfg, target_params["emb"])
    parallel = dc.arch == "fasteagle_par"
    beta = tc.beta if dc.feature_loss else 0.0
    w = jnp.asarray(_layer_weights(n, tc.layer_decay))
    t_len = tokens.shape[1] - 1  # teacher arrays are length T
    a = t_len - n  # usable anchors per sequence

    def loss_fn(p, batch_tok, batch_logits, batch_feats):
        b = batch_tok.shape[0]
        anchors_feats = batch_feats[:, :a]
        next_toks = batch_tok[:, 1: a + 1]
        pos = jnp.broadcast_to(jnp.arange(a, dtype=jnp.int32)[None], (b, a))
        mask = causal_mask(b, a, a)
        dkv = jnp.zeros(fe_kv_shape(cfg, b, a), jnp.float32)
        logits, hidden, _ = fe_apply(
            p, anchors_feats, next_toks, pos, mask, jnp.zeros((b,), jnp.int32),
            dkv, cfg=cfg, parallel=parallel, use_pallas=False,
        )
        total = 0.0
        for i in range(1, n + 1):
            teacher = jax.lax.dynamic_slice_in_dim(batch_logits, i, a, axis=1)
            ftgt = jax.lax.dynamic_slice_in_dim(batch_feats, i, a, axis=1)[..., 2 * d:]
            nxt = jax.lax.dynamic_slice_in_dim(batch_tok, i, a, axis=1)
            valid = (nxt != PAD).astype(jnp.float32)
            ce = soft_ce(logits[:, :, i - 1], teacher, valid)
            fl = feat_loss(hidden[:, :, i - 1], ftgt, valid)
            total = total + w[i - 1] * (tc.alpha * ce + beta * fl)
        return total

    opt = adamw_init(params)

    @jax.jit
    def step(p, o, bt, bl, bf):
        l, g = jax.value_and_grad(loss_fn)(p, bt, bl, bf)
        p, o = adamw_update(p, g, o, lr=tc.drafter_lr, tc=tc, frozen=("emb",))
        return p, o, l

    rng = np.random.default_rng(tc.seed + 2)
    losses = []
    t0 = time.time()
    for s in range(tc.drafter_steps):
        idx = rng.integers(0, tokens.shape[0], tc.batch)
        params, opt, l = step(params, opt, jnp.asarray(tokens[idx]),
                              jnp.asarray(t_logits[idx]), jnp.asarray(t_feats[idx]))
        losses.append(float(l))
        if s % 100 == 0 or s == tc.drafter_steps - 1:
            log(f"  {dc.name}[{cfg.name}] step {s} loss {float(l):.4f} "
                f"({time.time() - t0:.0f}s)")
    return params, losses


def train_eagle(cfg: TargetConfig, dc: DrafterConfig, tc: TrainConfig,
                target_params: Dict, tokens: np.ndarray,
                t_logits: np.ndarray, t_feats: np.ndarray,
                log: Callable[[str], None]) -> Tuple[Dict, List[float]]:
    """EAGLE baseline. ``rollout=True`` (EAGLE-3-like) adds two
    training-time-test steps that feed the drafter its own hidden states;
    ``rollout=False`` with ``multi_level=False`` is the EAGLE-2-like,
    teacher-forced, top-feature-only variant (degrades with depth, Fig. 3).
    """
    d = cfg.d_model
    key = jax.random.PRNGKey(tc.seed + 3)
    params = init_eagle(key, cfg, target_params["emb"], multi_level=dc.multi_level)
    n_roll = 3 if dc.rollout else 1
    w = jnp.asarray(_layer_weights(n_roll, tc.layer_decay))
    t_len = tokens.shape[1] - 1
    a = t_len - (n_roll + 1)

    def loss_fn(p, batch_tok, batch_logits, batch_feats):
        b = batch_tok.shape[0]
        feats_in = batch_feats[:, :a] if dc.multi_level else batch_feats[:, :a, 2 * d:]
        pos = jnp.broadcast_to(jnp.arange(a, dtype=jnp.int32)[None], (b, a))
        mask = causal_mask(b, a, a)
        total = 0.0
        h = None
        for s in range(1, n_roll + 1):
            nxt_in = jax.lax.dynamic_slice_in_dim(batch_tok, s, a, axis=1)
            ekv = jnp.zeros(eg_kv_shape(cfg, b, a), jnp.float32)
            logits, h, _ = eg_apply(
                p, feats_in if s == 1 else h, nxt_in, pos, mask,
                jnp.zeros((b,), jnp.int32), ekv, cfg=cfg, first=(s == 1),
                use_pallas=False,
            )
            teacher = jax.lax.dynamic_slice_in_dim(batch_logits, s, a, axis=1)
            ftgt = jax.lax.dynamic_slice_in_dim(batch_feats, s, a, axis=1)[..., 2 * d:]
            tgt_tok = jax.lax.dynamic_slice_in_dim(batch_tok, s + 1, a, axis=1)
            valid = (tgt_tok != PAD).astype(jnp.float32)
            ce = soft_ce(logits, teacher, valid)
            fl = feat_loss(h, ftgt, valid)
            total = total + w[s - 1] * (tc.alpha * ce + tc.beta * fl)
        return total

    opt = adamw_init(params)

    @jax.jit
    def step(p, o, bt, bl, bf):
        l, g = jax.value_and_grad(loss_fn)(p, bt, bl, bf)
        p, o = adamw_update(p, g, o, lr=tc.drafter_lr, tc=tc, frozen=("emb",))
        return p, o, l

    rng = np.random.default_rng(tc.seed + 4)
    losses = []
    t0 = time.time()
    for s in range(tc.drafter_steps):
        idx = rng.integers(0, tokens.shape[0], tc.batch)
        params, opt, l = step(params, opt, jnp.asarray(tokens[idx]),
                              jnp.asarray(t_logits[idx]), jnp.asarray(t_feats[idx]))
        losses.append(float(l))
        if s % 100 == 0 or s == tc.drafter_steps - 1:
            log(f"  {dc.name}[{cfg.name}] step {s} loss {float(l):.4f} "
                f"({time.time() - t0:.0f}s)")
    return params, losses


def train_medusa(cfg: TargetConfig, tc: TrainConfig, target_params: Dict,
                 tokens: np.ndarray, t_logits: np.ndarray, t_feats: np.ndarray,
                 log: Callable[[str], None]) -> Tuple[Dict, List[float]]:
    k = MEDUSA_HEADS
    key = jax.random.PRNGKey(tc.seed + 5)
    params = init_medusa(key, cfg, target_params["emb"])
    w = jnp.asarray(_layer_weights(k, tc.layer_decay))
    t_len = tokens.shape[1] - 1
    a = t_len - k

    def loss_fn(p, batch_tok, batch_logits, batch_feats):
        logits = medusa_apply(p, batch_feats[:, :a])  # [B, a, K, V]
        total = 0.0
        for i in range(1, k + 1):
            teacher = jax.lax.dynamic_slice_in_dim(batch_logits, i, a, axis=1)
            tgt_tok = jax.lax.dynamic_slice_in_dim(batch_tok, i, a, axis=1)
            valid = (tgt_tok != PAD).astype(jnp.float32)
            total = total + w[i - 1] * soft_ce(logits[:, :, i - 1], teacher, valid)
        return total

    opt = adamw_init(params)

    @jax.jit
    def step(p, o, bt, bl, bf):
        l, g = jax.value_and_grad(loss_fn)(p, bt, bl, bf)
        p, o = adamw_update(p, g, o, lr=tc.drafter_lr, tc=tc, frozen=("emb",))
        return p, o, l

    rng = np.random.default_rng(tc.seed + 6)
    losses = []
    for s in range(tc.drafter_steps):
        idx = rng.integers(0, tokens.shape[0], tc.batch)
        params, opt, l = step(params, opt, jnp.asarray(tokens[idx]),
                              jnp.asarray(t_logits[idx]), jnp.asarray(t_feats[idx]))
        losses.append(float(l))
        if s % 200 == 0 or s == tc.drafter_steps - 1:
            log(f"  medusa[{cfg.name}] step {s} loss {float(l):.4f}")
    return params, losses


def train_sps(sps_cfg: TargetConfig, tc: TrainConfig, tokens: np.ndarray,
              log: Callable[[str], None]) -> Tuple[Dict, List[float]]:
    """The SpS baseline's independent tiny draft LM (plain next-token CE)."""
    tc_sps = TrainConfig(**{**tc.__dict__, "target_steps": tc.drafter_steps,
                            "seed": tc.seed + 7})
    return train_target(sps_cfg, tc_sps, tokens, log)
