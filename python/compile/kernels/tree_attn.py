"""Pallas tree-attention kernel (L1) — parallel draft-tree verification.

The paper (§2.4) verifies all nodes of the constrained draft tree in a
single target forward using *tree attention*: each of the M draft rows
attends to the committed prefix plus its tree ancestors, encoded as an
additive mask. This kernel is the TPU-shaped implementation of that
primitive, and is also reused for chunked prefill (causal mask) and for
the cascade drafter's anchor attention — the mask carries the structure.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): instead of a CUDA
warp-per-row pattern, the grid is (batch, head); each program keeps its
query rows [T, hd] VMEM-resident while the K/V context for its KV head
streams through. GQA is expressed in the BlockSpec index maps (query head
h reads KV head h // group) rather than by materializing repeated KV, so
no HBM traffic is spent expanding grouped KV. Dims are padded to 8/16
multiples for MXU tiles. ``interpret=True`` everywhere: the CPU PJRT
plugin cannot run Mosaic custom-calls; real-TPU numbers are estimated in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9


def _tree_attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, scale: float):
    """One (batch, head) program: out = softmax(q k^T * scale + mask) v.

    Block shapes (leading blocked dims squeezed by BlockSpec):
      q_ref    [T, hd]   — this head's query rows (VMEM-resident)
      k_ref    [S, hd]   — the matching *KV head* (GQA via index_map)
      v_ref    [S, hd]
      mask_ref [T, S]    — additive tree/causal/prefix mask
      o_ref    [T, hd]
    """
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    mask = mask_ref[...]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    scores = scores + mask
    # numerically-stable softmax in-register
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    probs = e / denom
    o_ref[...] = jnp.dot(probs, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tree_attention(
    q: jnp.ndarray,  # [B, T, H, hd]
    k: jnp.ndarray,  # [B, S, KH, hd]
    v: jnp.ndarray,  # [B, S, KH, hd]
    mask: jnp.ndarray,  # [B, T, S] additive
    interpret: bool = True,
) -> jnp.ndarray:  # [B, T, H, hd]
    b, t, h, hd = q.shape
    s, kh = k.shape[1], k.shape[2]
    group = h // kh
    scale = 1.0 / float(hd) ** 0.5

    grid = (b, h)
    return pl.pallas_call(
        functools.partial(_tree_attn_kernel, scale=scale),
        grid=grid,
        in_specs=[
            # q[b, :, h, :] — None entries are squeezed from the kernel ref
            pl.BlockSpec((None, t, None, hd), lambda bi, hi: (bi, 0, hi, 0)),
            # k[b, :, h // group, :] — GQA head sharing via index_map
            pl.BlockSpec((None, s, None, hd), lambda bi, hi: (bi, 0, hi // group, 0)),
            pl.BlockSpec((None, s, None, hd), lambda bi, hi: (bi, 0, hi // group, 0)),
            # mask[b, :, :] shared across heads
            pl.BlockSpec((None, t, s), lambda bi, hi: (bi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, t, None, hd), lambda bi, hi: (bi, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, h, hd), q.dtype),
        interpret=interpret,
    )(q, k, v, mask)


def vmem_bytes(t: int, s: int, hd: int) -> int:
    """Estimated VMEM footprint of one program instance (f32)."""
    per = t * hd + 2 * s * hd + t * s + t * hd  # q, k+v, mask, out
    scratch = 2 * t * s + 2 * t  # scores+probs, max+denom
    return 4 * (per + scratch)
