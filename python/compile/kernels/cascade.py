"""Pallas fused-MLP kernel (L1) — the cascade layer's feed-forward half.

The FastEagle cascade (paper §2.1) replaces N autoregressive drafter
steps with N structurally-cascaded decoder layers executed in one forward
pass. Each cascade layer is (anchor attention) + (position-wise MLP); the
attention half reuses the tree-attention kernel (`tree_attn.py`) with an
anchor-causal mask, and this module provides the fused MLP half:

    y = x + GELU(rms(x) @ W1 + b1) @ W2 + b2

fused into a single kernel so the residual stream never leaves VMEM
between the two matmuls. On a real TPU the whole 6-layer cascade's
weights (~2.6 MB f32 at d=192) fit in VMEM, making the entire draft a
single MXU-resident pass — the TPU analogue of the paper's "single
forward pass" (DESIGN.md §Hardware-Adaptation).

Grid: (B, T-tiles). ffn is looped in ff-tile chunks with a VMEM
accumulator so the kernel scales to ffn ≫ VMEM. interpret=True for the
CPU PJRT plugin (see tree_attn.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gelu(h):
    return 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h**3)))


def _fused_mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, *, ff_tiles: int):
    """One (batch, row-tile) program.

    x_ref  [Tt, d]      — row tile of the residual stream (VMEM-resident)
    w1_ref [d, ffn], b1_ref [ffn], w2_ref [ffn, d], b2_ref [d]
    o_ref  [Tt, d]      — mlp(x) (residual added by caller)

    The ffn dimension is processed in ``ff_tiles`` chunks: h-tile = GELU(x
    @ W1-tile) is immediately contracted with the matching W2-tile into a
    [Tt, d] accumulator, so peak live VMEM is O(Tt*d + d*ff_tile) instead
    of O(Tt*ffn).
    """
    x = x_ref[...]
    tt, d = x.shape
    ffn = w1_ref.shape[1]
    tile = ffn // ff_tiles
    acc = jnp.zeros((tt, d), dtype=jnp.float32)
    for i in range(ff_tiles):
        w1 = w1_ref[:, i * tile:(i + 1) * tile]
        b1 = b1_ref[i * tile:(i + 1) * tile]
        w2 = w2_ref[i * tile:(i + 1) * tile, :]
        h = jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1[None, :]
        h = _gelu(h)
        acc = acc + jnp.dot(h, w2, preferred_element_type=jnp.float32)
    o_ref[...] = acc + b2_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("interpret", "ff_tiles", "row_tile"))
def fused_mlp(
    x: jnp.ndarray,  # [B, T, d]
    w1: jnp.ndarray,  # [d, ffn]
    b1: jnp.ndarray,  # [ffn]
    w2: jnp.ndarray,  # [ffn, d]
    b2: jnp.ndarray,  # [d]
    interpret: bool = True,
    ff_tiles: int = 2,
    row_tile: int = 0,  # 0 -> whole T in one tile
) -> jnp.ndarray:  # [B, T, d]
    b, t, d = x.shape
    ffn = w1.shape[1]
    assert ffn % ff_tiles == 0, (ffn, ff_tiles)
    tt = t if row_tile == 0 else row_tile
    assert t % tt == 0, (t, tt)
    grid = (b, t // tt)
    return pl.pallas_call(
        functools.partial(_fused_mlp_kernel, ff_tiles=ff_tiles),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, tt, d), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((d, ffn), lambda bi, ti: (0, 0)),
            pl.BlockSpec((ffn,), lambda bi, ti: (0,)),
            pl.BlockSpec((ffn, d), lambda bi, ti: (0, 0)),
            pl.BlockSpec((d,), lambda bi, ti: (0,)),
        ],
        out_specs=pl.BlockSpec((None, tt, d), lambda bi, ti: (bi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, d), x.dtype),
        interpret=interpret,
    )(x, w1, b1, w2, b2)


def vmem_bytes(tt: int, d: int, ffn: int, ff_tiles: int) -> int:
    """Estimated VMEM footprint of one program instance (f32)."""
    tile = ffn // ff_tiles
    live = tt * d * 2 + d * ffn + ffn + ffn * d + d  # x, acc, weights
    scratch = tt * tile  # h tile
    return 4 * (live + scratch)
