"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

These are the ground truth the Pallas kernels in ``tree_attn.py`` and
``cascade.py`` are tested against (pytest + hypothesis sweeps in
``python/tests/test_kernels.py``). They are also used directly by the L2
model code when ``use_pallas=False`` so kernel-vs-model equivalence can be
asserted end-to-end.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_gqa_attention_ref(
    q: jnp.ndarray,  # [B, T, H, hd]
    k: jnp.ndarray,  # [B, S, KH, hd]
    v: jnp.ndarray,  # [B, S, KH, hd]
    mask: jnp.ndarray,  # [B, T, S] additive (0 / -inf)
) -> jnp.ndarray:  # [B, T, H, hd]
    """Tree/causal attention with grouped-query KV, additive mask.

    This single primitive covers every attention in the system: target
    prefill (causal-within-chunk + prefix mask), tree verification
    (ancestor mask, paper §2.4), and the drafter cascade's anchor
    attention (paper §2.1) — the mask encodes the structure.
    """
    b, t, h, hd = q.shape
    kh = k.shape[2]
    group = h // kh
    scale = 1.0 / jnp.sqrt(jnp.array(hd, dtype=q.dtype))
    # expand kv heads to full heads
    k_full = jnp.repeat(k, group, axis=2)  # [B, S, H, hd]
    v_full = jnp.repeat(v, group, axis=2)
    # [B, H, T, S]
    scores = jnp.einsum("bthd,bshd->bhts", q, k_full) * scale
    scores = scores + mask[:, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, v_full)
    return out


def fused_mlp_ref(
    x: jnp.ndarray,  # [B, T, d]
    w1: jnp.ndarray,  # [d, ffn]
    b1: jnp.ndarray,  # [ffn]
    w2: jnp.ndarray,  # [ffn, d]
    b2: jnp.ndarray,  # [d]
) -> jnp.ndarray:  # [B, T, d] (the MLP output, residual added by caller)
    """Position-wise feed-forward with GELU, the cascade layer's second half."""
    h = x @ w1 + b1
    h = 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h**3)))
    return h @ w2 + b2
