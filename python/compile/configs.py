"""Model / drafting / training configuration for the FastEagle reproduction.

Everything here is the single source of truth shared by the JAX model code
(L2), the Pallas kernels (L1), the trainer, and — via ``spec.json`` emitted
by ``aot.py`` — the Rust coordinator (L3).

The targets are tiny byte-level LLaMA-style models standing in for the
paper's Vicuna-13B / LLaMA-3.1-8B / LLaMA-3.3-70B / DeepSeek-R1-Distill
(see DESIGN.md §Substitutions).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Tuple

# ----------------------------------------------------------------------------
# Vocabulary: byte-level + specials, padded to a multiple of 16 for tiling.
# ----------------------------------------------------------------------------
BOS = 256
EOS = 257
PAD = 258
VOCAB = 272  # 256 bytes + 3 specials + 13 reserved, = 17 * 16

# Draft-tree configuration (paper §2.2, scaled: the paper uses depth 7 /
# top-k 10 on A100; we use depth 6 / top-k 3 on the tiny CPU testbed).
DRAFT_DEPTH = 6  # N cascade layers == draft depth
TREE_TOP_K = 3
# Verification rows per cycle = 1 root (the pending token, always
# committed — it was sampled from the true target distribution) + k
# candidates per level under Backbone Expansion. O(N·k), linear in both.
TREE_NODES = 1 + DRAFT_DEPTH * TREE_TOP_K  # == 19 rows incl. root

# Verify-executable row counts emitted per target (M = rows per call,
# always including the root row):
#   1  -> vanilla decoding (root only)
#   3  -> Table-3 chains (root + max chain length 2, paper's setup)
#   7  -> chain ablation "w/o Constrained Tree" (root + depth-6 chain);
#         also fits the SpS chain (root + 5)
#   13 -> Medusa tree (root + 4 heads * k)
#   19 -> full constrained tree
VERIFY_MS = (1, 3, 7, 13, TREE_NODES)

# Batched decode variants for the continuous-batching study (Table 3).
BATCH_SIZES = (2, 4, 8, 16)

PREFILL_CHUNK = 32  # target prompt ingestion chunk
DRAFTER_PREFILL_CHUNKS = (32, 8)  # prompt ingestion / per-cycle accepted chunk

MAX_SEQ = 256
MEDUSA_HEADS = 4
SPS_CHAIN = 5


@dataclasses.dataclass(frozen=True)
class TargetConfig:
    """A tiny LLaMA-style target model (pre-norm, GQA, learned abs. pos)."""

    name: str
    stands_for: str  # which paper model this is a stand-in for
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    ffn: int
    taps: Tuple[int, int, int]  # low/mid/high feature-tap layer indices
    max_seq: int = MAX_SEQ
    vocab: int = VOCAB
    # training-mixture weights over the 5 synthetic tasks
    mixture: Tuple[float, ...] = (1.0, 1.0, 1.0, 1.0, 1.0)

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def feat_dim(self) -> int:
        return 3 * self.d_model


@dataclasses.dataclass(frozen=True)
class DrafterConfig:
    """Configuration of one drafter weight-set trained against a target."""

    name: str  # fasteagle | fasteagle_nofeat | fasteagle_par | eagle3 | eagle2 | medusa | sps
    arch: str  # fasteagle | fasteagle_par | eagle | medusa | sps
    # training ablation switches (paper §2.3 / Table 2)
    feature_loss: bool = True  # beta > 0
    multi_level: bool = True  # EAGLE-3-style 3-tap input (False -> EAGLE-2-like)
    rollout: bool = True  # training-time-test style rollout steps (False -> teacher forcing)


# The four paper targets -> three distinct architectures + one re-mixture.
TARGETS: Dict[str, TargetConfig] = {
    "base": TargetConfig(
        name="base", stands_for="Vicuna-13B", d_model=192, n_layers=6,
        n_heads=6, n_kv_heads=2, head_dim=32, ffn=576, taps=(1, 3, 5),
    ),
    "mid": TargetConfig(
        # n_heads must be divisible by n_kv_heads (GQA grouping) -> MQA here
        name="mid", stands_for="LLaMA-Instruct-3.1-8B", d_model=224, n_layers=7,
        n_heads=7, n_kv_heads=1, head_dim=32, ffn=672, taps=(1, 3, 6),
    ),
    "large": TargetConfig(
        name="large", stands_for="LLaMA-Instruct-3.3-70B", d_model=256, n_layers=8,
        n_heads=8, n_kv_heads=2, head_dim=32, ffn=768, taps=(2, 4, 7),
    ),
    "baser": TargetConfig(
        name="baser", stands_for="DeepSeek-R1-Distill-LLaMA-8B", d_model=192,
        n_layers=6, n_heads=6, n_kv_heads=2, head_dim=32, ffn=576, taps=(1, 3, 5),
        mixture=(0.5, 0.5, 3.0, 0.5, 0.5),  # math-heavy, like OpenThoughts-math
    ),
}

# Drafter weight-sets per target. The full matrix is only trained for "base"
# (the paper's ablations + Fig.3 + SpS/Medusa rows all use one target);
# the other targets get the two headline methods.
DRAFTERS_FULL: List[DrafterConfig] = [
    DrafterConfig("fasteagle", "fasteagle"),
    DrafterConfig("fasteagle_nofeat", "fasteagle", feature_loss=False),
    DrafterConfig("fasteagle_par", "fasteagle_par"),
    DrafterConfig("eagle3", "eagle"),
    DrafterConfig("eagle2", "eagle", multi_level=False, rollout=False),
    DrafterConfig("medusa", "medusa"),
    DrafterConfig("sps", "sps"),
]
DRAFTERS_HEADLINE: List[DrafterConfig] = [
    DrafterConfig("fasteagle", "fasteagle"),
    DrafterConfig("eagle3", "eagle"),
]

DRAFTER_SETS: Dict[str, List[DrafterConfig]] = {
    "base": DRAFTERS_FULL,
    "mid": DRAFTERS_HEADLINE,
    "large": DRAFTERS_HEADLINE,
    "baser": DRAFTERS_HEADLINE,
}

# SpS draft LM (a separate tiny model, paper's "standard speculative
# sampling" baseline): 2 layers, narrower.
SPS_LAYERS = 2


def sps_config(tc: TargetConfig) -> TargetConfig:
    return TargetConfig(
        name=tc.name + "_sps", stands_for="SpS draft LM", d_model=96,
        n_layers=SPS_LAYERS, n_heads=3, n_kv_heads=1, head_dim=32, ffn=288,
        taps=(0, 0, SPS_LAYERS - 1), max_seq=tc.max_seq,
    )


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Training hyper-parameters.

    Optimizer follows the paper §3 Implementation: AdamW,
    (beta1, beta2) = (0.9, 0.95), gradient clip 0.5. The paper's lr of 5e-5
    is tuned for epochs over ~500K ShareGPT/UltraChat samples; our
    from-scratch tiny models need a larger lr to converge within the
    CPU-minute budget — recorded as a deviation in EXPERIMENTS.md.
    """

    seq_len: int = 96
    batch: int = 16
    target_steps: int = 700
    drafter_steps: int = 500
    target_lr: float = 3e-3
    drafter_lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.95
    weight_decay: float = 0.01
    grad_clip: float = 0.5
    # paper §2.3 uses w_i = 0.9^{N-i}, alpha = 0.1, beta = 1.0 with
    # Smooth-L1 *summed* over unit-scale LLaMA features. Our tiny
    # from-scratch targets have much larger feature magnitudes, so we use
    # mean-scaled Smooth-L1 with a recalibrated balance (see
    # EXPERIMENTS.md §Deviations); w_i is unchanged.
    layer_decay: float = 0.9
    alpha: float = 1.0
    beta: float = 0.05
    n_train_seqs: int = 512
    seed: int = 0


def train_config() -> TrainConfig:
    """FE_FAST=1 shrinks everything to smoke scale (CI / pytest);
    FE_TARGET_STEPS / FE_DRAFTER_STEPS override step counts for tuning."""
    if os.environ.get("FE_FAST", "0") == "1":
        tc = TrainConfig(
            seq_len=64, batch=8, target_steps=30, drafter_steps=20,
            n_train_seqs=64,
        )
    else:
        tc = TrainConfig()
    ts = int(os.environ.get("FE_TARGET_STEPS", tc.target_steps))
    ds = int(os.environ.get("FE_DRAFTER_STEPS", tc.drafter_steps))
    if (ts, ds) != (tc.target_steps, tc.drafter_steps):
        tc = dataclasses.replace(tc, target_steps=ts, drafter_steps=ds)
    return tc


TASKS = ("dialog", "code", "math", "inst", "news")
# Which paper benchmark each synthetic task stands in for.
TASK_STANDS_FOR = {
    "dialog": "MT-Bench",
    "code": "HumanEval",
    "math": "GSM8K",
    "inst": "Alpaca",
    "news": "CNN/DM",
}
