"""AOT export (the only python entry point): train everything, lower every
inference executable to HLO *text*, and write the artifact tree the Rust
coordinator consumes.

HLO text — not ``XlaComputation.serialize()`` — is the interchange format:
jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects; the text parser reassigns ids
(see /opt/xla-example/README.md).

Artifact tree:

    artifacts/
      manifest.json            global: targets, tasks, tree params
      train_log.json           loss curves (EXPERIMENTS.md provenance)
      prompts/<task>.json      held-out eval prompts (JSON string array)
      <target>/
        spec.json              dims + executable inventory
        hlo/<exec>.hlo.txt     lowered executables
        hlo/<exec>.io.json     flattened input/output manifests
        weights/<set>.few      FEW1 weight sets (target, fasteagle, ...)

Run: ``cd python && python -m compile.aot --out ../artifacts``
(FE_FAST=1 for a smoke-scale build).
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import train as train_mod
from .configs import (BATCH_SIZES, BOS, DRAFT_DEPTH, DRAFTER_SETS, EOS,
                      MEDUSA_HEADS, PAD, PREFILL_CHUNK, SPS_CHAIN, TARGETS,
                      TASK_STANDS_FOR, TASKS, TREE_NODES, TREE_TOP_K, VERIFY_MS,
                      VOCAB, DrafterConfig, TargetConfig, sps_config,
                      train_config)
from .drafters import eg_apply, eg_kv_shape, fe_apply, fe_kv_shape, medusa_apply
from .model import kv_shape, target_apply

F32 = jnp.float32
I32 = jnp.int32


# ----------------------------------------------------------------------------
# lowering helpers
# ----------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _path_name(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:  # GetAttrKey etc.
            parts.append(str(k))
    return "/".join(parts)


def flatten_named(tree) -> List[Tuple[str, jnp.ndarray]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(_path_name(p), v) for p, v in leaves]


def _spec_of(x) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def lower_exec(
    hlo_dir: str,
    name: str,
    fn: Callable,
    weights_example,
    args: List[Tuple[str, Tuple[int, ...], object, str]],  # (name, shape, dtype, kind)
    log: Callable[[str], None],
) -> Dict:
    """Lower ``fn(weights, *args) -> dict`` and write hlo + io manifest."""
    t0 = time.time()
    w_spec = jax.tree_util.tree_map(_spec_of, weights_example)
    arg_specs = [jax.ShapeDtypeStruct(shape, dtype) for (_, shape, dtype, _) in args]
    lowered = jax.jit(fn).lower(w_spec, *arg_specs)
    text = to_hlo_text(lowered)
    with open(os.path.join(hlo_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)

    inputs = []
    for wname, leaf in flatten_named(w_spec):
        inputs.append({
            "name": wname, "kind": "weight",
            "shape": list(leaf.shape), "dtype": str(leaf.dtype),
        })
    for aname, shape, dtype, kind in args:
        inputs.append({
            "name": aname, "kind": kind,
            "shape": list(shape), "dtype": np.dtype(dtype).name,
        })
    # jax.jit prunes unused args (DCE) from the lowered module's
    # signature — the manifest must list only the surviving parameters,
    # in order, or the PJRT call will mismatch arity.
    kept = lowered._lowering.compile_args.get("kept_var_idx")
    if kept is not None:
        inputs = [io for i, io in enumerate(inputs) if i in kept]

    out_example = jax.eval_shape(fn, w_spec, *arg_specs)
    outputs = [
        {"name": oname, "shape": list(leaf.shape), "dtype": str(leaf.dtype)}
        for oname, leaf in flatten_named(out_example)
    ]
    io = {"name": name, "inputs": inputs, "outputs": outputs}
    with open(os.path.join(hlo_dir, f"{name}.io.json"), "w") as f:
        json.dump(io, f)
    log(f"  lowered {name} ({len(text)//1024} KiB, {time.time()-t0:.1f}s)")
    return io


# ----------------------------------------------------------------------------
# executable builders (closures over a TargetConfig)
# ----------------------------------------------------------------------------

def tgt_exec(cfg: TargetConfig, m: int, b: int, with_feats: bool = True):
    s = cfg.max_seq

    def fn(w, tokens, positions, mask, cache_len, kv):
        logits, feats, kv2 = target_apply(
            w, tokens, positions, mask, cache_len, kv, cfg=cfg, use_pallas=True)
        out = {"kv": kv2, "logits": logits}
        if with_feats:
            out["feats"] = feats
        return out

    args = [
        ("tokens", (b, m), np.int32, "arg"),
        ("positions", (b, m), np.int32, "arg"),
        ("mask", (b, m, s), np.float32, "arg"),
        ("cache_len", (b,), np.int32, "arg"),
        ("kv", kv_shape(cfg, b), np.float32, "state"),
    ]
    return fn, args


def fe_exec(cfg: TargetConfig, t: int, b: int, parallel: bool):
    c = cfg.max_seq

    def fn(w, feats, next_tokens, anchor_pos, mask, ctx_len, dkv):
        logits, _, dkv2 = fe_apply(
            w, feats, next_tokens, anchor_pos, mask, ctx_len, dkv,
            cfg=cfg, parallel=parallel, use_pallas=True)
        return {"dkv": dkv2, "logits": logits}

    args = [
        ("feats", (b, t, 3 * cfg.d_model), np.float32, "arg"),
        ("next_tokens", (b, t), np.int32, "arg"),
        ("anchor_pos", (b, t), np.int32, "arg"),
        ("mask", (b, t, c), np.float32, "arg"),
        ("ctx_len", (b,), np.int32, "arg"),
        ("dkv", fe_kv_shape(cfg, b), np.float32, "state"),
    ]
    return fn, args


def eg_exec(cfg: TargetConfig, t: int, b: int, first: bool, multi_level: bool):
    c = cfg.max_seq
    fin = (3 * cfg.d_model if multi_level else cfg.d_model) if first else cfg.d_model

    def fn(w, feat_in, tokens, anchor_pos, mask, ctx_len, ekv):
        logits, h, ekv2 = eg_apply(
            w, feat_in, tokens, anchor_pos, mask, ctx_len, ekv,
            cfg=cfg, first=first, use_pallas=True)
        return {"ekv": ekv2, "h": h, "logits": logits}

    args = [
        ("feat_in", (b, t, fin), np.float32, "arg"),
        ("tokens", (b, t), np.int32, "arg"),
        ("anchor_pos", (b, t), np.int32, "arg"),
        ("mask", (b, t, c), np.float32, "arg"),
        ("ctx_len", (b,), np.int32, "arg"),
        ("ekv", eg_kv_shape(cfg, b), np.float32, "state"),
    ]
    return fn, args


def medusa_exec(cfg: TargetConfig, b: int = 1):
    def fn(w, feats):
        return {"logits": medusa_apply(w, feats)}

    args = [("feats", (b, 1, 3 * cfg.d_model), np.float32, "arg")]
    return fn, args


# ----------------------------------------------------------------------------
# per-target plan
# ----------------------------------------------------------------------------

def exec_plan(cfg: TargetConfig) -> List[Tuple[str, Tuple]]:
    """(name, (builder, kwargs)) pairs to lower for this target."""
    scfg = sps_config(cfg)
    plan: List[Tuple[str, Tuple]] = []
    ms = sorted(set(VERIFY_MS) | {PREFILL_CHUNK})
    for m in ms:
        plan.append((f"tgt_m{m}", (tgt_exec, dict(cfg=cfg, m=m, b=1))))
    # drafters present on every target
    for t in (1, 8, 32):
        plan.append((f"fe_t{t}", (fe_exec, dict(cfg=cfg, t=t, b=1, parallel=False))))
        plan.append((f"eg3_first_t{t}",
                     (eg_exec, dict(cfg=cfg, t=t, b=1, first=True, multi_level=True))))
    plan.append(("eg_next_t1",
                 (eg_exec, dict(cfg=cfg, t=1, b=1, first=False, multi_level=True))))
    if cfg.name == "base":
        # full baseline + ablation matrix
        for t in (1, 8, 32):
            plan.append((f"fe_par_t{t}",
                         (fe_exec, dict(cfg=cfg, t=t, b=1, parallel=True))))
            plan.append((f"eg2_first_t{t}",
                         (eg_exec, dict(cfg=cfg, t=t, b=1, first=True, multi_level=False))))
        plan.append(("medusa", (medusa_exec, dict(cfg=cfg))))
        for m in (1, 8, 32):
            plan.append((f"sps_m{m}",
                         (tgt_exec, dict(cfg=scfg, m=m, b=1, with_feats=False))))
    if cfg.name == "mid":
        # continuous-batching study (Table 3): chain length 2, no tree.
        # m=1 -> batched vanilla; m=3 -> root + chain-2 rows.
        for b in BATCH_SIZES:
            for m in (1, 3):
                plan.append((f"tgt_m{m}_b{b}", (tgt_exec, dict(cfg=cfg, m=m, b=b))))
            for t in (1, 8):
                plan.append((f"fe_t{t}_b{b}",
                             (fe_exec, dict(cfg=cfg, t=t, b=b, parallel=False))))
                plan.append((f"eg3_first_t{t}_b{b}",
                             (eg_exec, dict(cfg=cfg, t=t, b=b, first=True, multi_level=True))))
            plan.append((f"eg_next_t1_b{b}",
                         (eg_exec, dict(cfg=cfg, t=1, b=b, first=False, multi_level=True))))
    return plan


def weights_example_for(name: str, trained: Dict[str, Dict]):
    """Pick the parameter pytree whose structure matches executable ``name``."""
    if name.startswith("tgt_"):
        return trained["target"]
    if name.startswith("sps_"):
        return trained["sps"]
    if name.startswith("fe_par"):
        return trained["fasteagle_par"]
    if name.startswith("fe_"):
        return trained["fasteagle"]
    if name.startswith("eg2_"):
        return trained["eagle2"]
    if name.startswith("eg"):
        return trained["eagle3"]
    if name.startswith("medusa"):
        return trained["medusa"]
    raise KeyError(name)


# ----------------------------------------------------------------------------
# main
# ----------------------------------------------------------------------------

def build_target(cfg: TargetConfig, out_dir: str, tc, log) -> Dict:
    from .fmt import write_weights

    tdir = os.path.join(out_dir, cfg.name)
    hlo_dir = os.path.join(tdir, "hlo")
    wdir = os.path.join(tdir, "weights")
    os.makedirs(hlo_dir, exist_ok=True)
    os.makedirs(wdir, exist_ok=True)

    log(f"[{cfg.name}] training target ({cfg.stands_for} stand-in)")
    texts = data_mod.corpus(tc.n_train_seqs, cfg.mixture, tc.seed)
    tokens = train_mod.tokenize_corpus(texts, tc.seq_len)
    losses: Dict[str, List[float]] = {}
    target_params, losses["target"] = train_mod.train_target(cfg, tc, tokens, log)
    t_logits, t_feats = train_mod.harvest(cfg, target_params, tokens)

    trained: Dict[str, Dict] = {"target": target_params}
    for dc in DRAFTER_SETS[cfg.name]:
        if dc.arch in ("fasteagle", "fasteagle_par"):
            p, l = train_mod.train_fasteagle(cfg, dc, tc, target_params, tokens,
                                             t_logits, t_feats, log)
        elif dc.arch == "eagle":
            p, l = train_mod.train_eagle(cfg, dc, tc, target_params, tokens,
                                         t_logits, t_feats, log)
        elif dc.arch == "medusa":
            p, l = train_mod.train_medusa(cfg, tc, target_params, tokens,
                                          t_logits, t_feats, log)
        elif dc.arch == "sps":
            p, l = train_mod.train_sps(sps_config(cfg), tc, tokens, log)
        else:
            raise ValueError(dc.arch)
        trained[dc.name] = p
        losses[dc.name] = l
    # structural aliases for executables shared between weight sets
    trained.setdefault("fasteagle_par", trained.get("fasteagle"))
    trained.setdefault("eagle2", trained.get("eagle3"))
    trained.setdefault("eagle3", trained.get("eagle3"))
    trained.setdefault("medusa", trained.get("medusa"))
    trained.setdefault("sps", trained.get("sps"))

    for set_name, params in trained.items():
        if params is None:
            continue
        write_weights(os.path.join(wdir, f"{set_name}.few"),
                      [(n, np.asarray(v)) for n, v in flatten_named(params)])

    execs = {}
    for name, (builder, kwargs) in exec_plan(cfg):
        wex = weights_example_for(name, trained)
        if wex is None:
            continue
        fn, args = builder(**kwargs)
        io = lower_exec(hlo_dir, name, fn, wex, args, log)
        execs[name] = {
            "m": kwargs.get("m"), "t": kwargs.get("t"), "b": kwargs.get("b", 1),
            "n_inputs": len(io["inputs"]), "n_outputs": len(io["outputs"]),
        }

    scfg = sps_config(cfg)
    spec = {
        "name": cfg.name,
        "stands_for": cfg.stands_for,
        "d_model": cfg.d_model, "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads,
        "head_dim": cfg.head_dim, "ffn": cfg.ffn,
        "taps": list(cfg.taps), "max_seq": cfg.max_seq, "vocab": cfg.vocab,
        "feat_dim": cfg.feat_dim,
        "bos": BOS, "eos": EOS, "pad": PAD,
        "prefill_chunk": PREFILL_CHUNK,
        "draft_depth": DRAFT_DEPTH, "tree_top_k": TREE_TOP_K,
        "tree_nodes": TREE_NODES, "medusa_heads": MEDUSA_HEADS,
        "sps_chain": SPS_CHAIN,
        "sps": {"d_model": scfg.d_model, "n_layers": scfg.n_layers,
                "n_kv_heads": scfg.n_kv_heads, "head_dim": scfg.head_dim},
        "drafter_sets": [dc.name for dc in DRAFTER_SETS[cfg.name]],
        "executables": execs,
        "batch_sizes": list(BATCH_SIZES) if cfg.name == "mid" else [1],
    }
    with open(os.path.join(tdir, "spec.json"), "w") as f:
        json.dump(spec, f, indent=1)
    return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    # "large" (the 70B stand-in) is opt-in: it doubles build time on a
    # 1-core box (see EXPERIMENTS.md §Deviations #4)
    ap.add_argument("--targets", default="base,mid,baser")
    args = ap.parse_args()
    tc = train_config()
    out_dir = args.out
    os.makedirs(os.path.join(out_dir, "prompts"), exist_ok=True)

    def log(msg: str) -> None:
        print(msg, flush=True)

    t0 = time.time()
    n_prompts = 16 if os.environ.get("FE_FAST", "0") == "1" else 64
    for task in TASKS:
        with open(os.path.join(out_dir, "prompts", f"{task}.json"), "w") as f:
            json.dump(data_mod.eval_prompts(task, n_prompts), f)

    all_losses = {}
    target_names = [t for t in args.targets.split(",") if t]
    for tname in target_names:
        all_losses[tname] = build_target(TARGETS[tname], out_dir, tc, log)

    # merge with any prior invocation (targets can be built in batches)
    log_path = os.path.join(out_dir, "train_log.json")
    if os.path.exists(log_path):
        with open(log_path) as f:
            prior = json.load(f)
        prior.update(all_losses)
        all_losses = prior
    man_path = os.path.join(out_dir, "manifest.json")
    if os.path.exists(man_path):
        with open(man_path) as f:
            prior_m = json.load(f)
        target_names = sorted(set(prior_m.get("targets", [])) | set(target_names))
    with open(log_path, "w") as f:
        json.dump(all_losses, f)
    manifest = {
        "targets": target_names,
        "tasks": list(TASKS),
        "task_stands_for": TASK_STANDS_FOR,
        "vocab": VOCAB,
        "fast_build": os.environ.get("FE_FAST", "0") == "1",
        "tree": {"depth": DRAFT_DEPTH, "top_k": TREE_TOP_K, "nodes": TREE_NODES},
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"artifacts complete in {time.time()-t0:.0f}s -> {out_dir}")


if __name__ == "__main__":
    main()
