"""Transformer building blocks (L2), shared by the target model, the
FastEagle cascade, the EAGLE baseline drafters, and the SpS draft LM.

All functions are pure: parameters are plain nested dicts of jnp arrays
(deterministically flattened by ``aot.py`` into the executable manifests),
state (KV caches) is threaded explicitly. Attention and the feed-forward
run through the Pallas kernels (L1) by default; ``use_pallas=False``
switches to the pure-jnp oracles so tests can assert kernel/model
equivalence end-to-end.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref as kref
from .kernels.cascade import fused_mlp
from .kernels.tree_attn import tree_attention

EPS = 1e-5
NEG = -1e9


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return x * w * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS)


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------

def init_block(key, d: int, n_heads: int, n_kv_heads: int, head_dim: int,
               ffn: int, n_layers_for_scale: int) -> Dict:
    ks = jax.random.split(key, 6)
    sd = 0.02
    out_sd = sd / (2.0 * n_layers_for_scale) ** 0.5
    return {
        "ln1": jnp.ones((d,), jnp.float32),
        "ln2": jnp.ones((d,), jnp.float32),
        "wq": jax.random.normal(ks[0], (d, n_heads * head_dim), jnp.float32) * sd,
        "wk": jax.random.normal(ks[1], (d, n_kv_heads * head_dim), jnp.float32) * sd,
        "wv": jax.random.normal(ks[2], (d, n_kv_heads * head_dim), jnp.float32) * sd,
        "wo": jax.random.normal(ks[3], (n_heads * head_dim, d), jnp.float32) * out_sd,
        "w1": jax.random.normal(ks[4], (d, ffn), jnp.float32) * sd,
        "b1": jnp.zeros((ffn,), jnp.float32),
        "w2": jax.random.normal(ks[5], (ffn, d), jnp.float32) * out_sd,
        "b2": jnp.zeros((d,), jnp.float32),
    }


# ----------------------------------------------------------------------------
# forward
# ----------------------------------------------------------------------------

def scatter_rows(
    cache: jnp.ndarray,  # [B, S, KH, hd]
    new: jnp.ndarray,  # [B, T, KH, hd]
    starts: jnp.ndarray,  # [B] i32 — per-request first slot
) -> jnp.ndarray:
    """Write T new rows into each request's cache at its own offset.

    Batched requests in a continuous-batching group have *different*
    prefix lengths, so the KV write offset is per-request. Expressed as a
    clipped gather + select (O(S) per call) rather than a scatter so it
    lowers to plain HLO the CPU PJRT plugin runs well.
    """
    b, s = cache.shape[0], cache.shape[1]
    t = new.shape[1]
    rel = jnp.arange(s, dtype=jnp.int32)[None, :] - starts[:, None]  # [B, S]
    inside = (rel >= 0) & (rel < t)
    idx = jnp.clip(rel, 0, t - 1)[:, :, None, None]
    idx = jnp.broadcast_to(idx, (b, s) + new.shape[2:])
    gathered = jnp.take_along_axis(new, idx, axis=1)
    return jnp.where(inside[:, :, None, None], gathered, cache)


def block_apply(
    p: Dict,
    x: jnp.ndarray,  # [B, T, d]
    k_cache: jnp.ndarray,  # [B, S, KH, hd]
    v_cache: jnp.ndarray,  # [B, S, KH, hd]
    mask: jnp.ndarray,  # [B, T, S] additive
    cache_len: jnp.ndarray,  # [B] i32: per-request slot for the T new rows
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    use_pallas: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pre-norm decoder block. The T new K/V rows are written into the
    caches at slots [cache_len[b], cache_len[b]+T); the mask decides
    visibility (prefix, causal-within-chunk, or tree ancestors — caller's
    contract).
    """
    b, t, d = x.shape
    h = rmsnorm(x, p["ln1"])
    q = (h @ p["wq"]).reshape(b, t, n_heads, head_dim)
    k_new = (h @ p["wk"]).reshape(b, t, n_kv_heads, head_dim)
    v_new = (h @ p["wv"]).reshape(b, t, n_kv_heads, head_dim)
    k_cache = scatter_rows(k_cache, k_new, cache_len)
    v_cache = scatter_rows(v_cache, v_new, cache_len)
    if use_pallas:
        attn = tree_attention(q, k_cache, v_cache, mask)
    else:
        attn = kref.masked_gqa_attention_ref(q, k_cache, v_cache, mask)
    x = x + attn.reshape(b, t, n_heads * head_dim) @ p["wo"]
    h2 = rmsnorm(x, p["ln2"])
    if use_pallas:
        x = x + fused_mlp(h2, p["w1"], p["b1"], p["w2"], p["b2"])
    else:
        x = x + kref.fused_mlp_ref(h2, p["w1"], p["b1"], p["w2"], p["b2"])
    return x, k_cache, v_cache


# ----------------------------------------------------------------------------
# mask helpers (training-side; the rust coordinator builds inference masks)
# ----------------------------------------------------------------------------

def causal_mask(b: int, t: int, s: int) -> jnp.ndarray:
    """[B, T, S] additive mask: row i sees slots 0..i (assumes cache_len=0)."""
    rows = jnp.arange(t)[:, None]
    cols = jnp.arange(s)[None, :]
    m = jnp.where(cols <= rows, 0.0, NEG).astype(jnp.float32)
    return jnp.broadcast_to(m[None], (b, t, s))
