"""FEW1 — the flat weights interchange format between python (L2, writer)
and the Rust runtime (L3, reader: ``rust/src/runtime/weights.rs``).

Layout (little-endian):

    magic   b"FEW1"
    u32     tensor count
    repeat:
      u16   name length, then name bytes (utf-8; '/'-joined pytree path)
      u8    dtype (0 = f32, 1 = i32)
      u8    ndim
      u32×ndim dims
      raw   data (dtype-sized, C order)

Tensor names match the "weight"-kind input names in each executable's
``*.io.json`` manifest, so the runtime can bind a weight set to any
executable by name lookup.
"""

from __future__ import annotations

import struct
from typing import List, Tuple

import numpy as np

MAGIC = b"FEW1"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write_weights(path: str, named: List[Tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(named)))
        for name, arr in named:
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in DTYPES:
                arr = arr.astype(np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_weights(path: str) -> List[Tuple[str, np.ndarray]]:
    """Reader (used by the python round-trip tests)."""
    out = []
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nl,) = struct.unpack("<H", f.read(2))
            name = f.read(nl).decode("utf-8")
            dt, nd = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{nd}I", f.read(4 * nd)) if nd else ()
            dtype = np.float32 if dt == 0 else np.int32
            n = int(np.prod(dims)) if dims else 1
            data = np.frombuffer(f.read(n * 4), dtype=dtype).reshape(dims)
            out.append((name, data))
    return out
