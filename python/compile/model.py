"""Target model (L2): tiny LLaMA-style decoder with EAGLE-3-style
multi-level feature taps.

One ``target_apply`` covers every target-side executable: chunked prefill,
vanilla decode, chain verification, and full tree verification differ
only in T (rows per call) and in the mask the Rust coordinator passes.
The KV cache crosses the PJRT boundary as an explicit input/output
(shape [L, 2, B, S, KH, hd]); the coordinator owns compaction/rollback.

Outputs per call: logits for every row (the verifier needs all of them),
the concatenated (l, m, h) tap features (drafter inputs, paper §2.1), and
the updated KV.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .configs import TargetConfig
from .layers import block_apply, causal_mask, init_block, rmsnorm


def init_target(key, cfg: TargetConfig) -> Dict:
    ks = jax.random.split(key, cfg.n_layers + 2)
    return {
        "emb": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32) * 0.02,
        "pos": jax.random.normal(ks[1], (cfg.max_seq, cfg.d_model), jnp.float32) * 0.02,
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        "blocks": {
            str(i): init_block(ks[2 + i], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.head_dim, cfg.ffn,
                               cfg.n_layers)
            for i in range(cfg.n_layers)
        },
    }


def kv_shape(cfg: TargetConfig, batch: int, s: int | None = None) -> Tuple[int, ...]:
    s = s or cfg.max_seq
    return (cfg.n_layers, 2, batch, s, cfg.n_kv_heads, cfg.head_dim)


def target_apply(
    params: Dict,
    tokens: jnp.ndarray,  # [B, T] i32
    positions: jnp.ndarray,  # [B, T] i32 (token positions, for pos-emb)
    mask: jnp.ndarray,  # [B, T, S] f32 additive
    cache_len: jnp.ndarray,  # [B] i32: per-request KV slot for the first new row
    kv: jnp.ndarray,  # [L, 2, B, S, KH, hd]
    *,
    cfg: TargetConfig,
    use_pallas: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (logits [B,T,V], feats [B,T,3d], kv')."""
    x = params["emb"][tokens] + params["pos"][positions]
    taps = []
    new_kv = []
    for i in range(cfg.n_layers):
        p = params["blocks"][str(i)]
        x, kc, vc = block_apply(
            p, x, kv[i, 0], kv[i, 1], mask, cache_len,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, use_pallas=use_pallas,
        )
        new_kv.append(jnp.stack([kc, vc]))
        if i in cfg.taps:
            taps.append(x)
    feats = jnp.concatenate(taps, axis=-1)  # [B, T, 3d]; [..., 2d:] is the 'h' tap
    xf = rmsnorm(x, params["ln_f"])
    logits = xf @ params["emb"].T  # tied LM head
    return logits, feats, jnp.stack(new_kv)


def target_train_apply(
    params: Dict,
    tokens: jnp.ndarray,  # [B, T]
    *,
    cfg: TargetConfig,
    use_pallas: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-causal teacher pass (training / feature harvesting): S == T,
    fresh KV. Returns (logits, feats)."""
    b, t = tokens.shape
    kv = jnp.zeros(kv_shape(cfg, b, t), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    mask = causal_mask(b, t, t)
    logits, feats, _ = target_apply(
        params, tokens, positions, mask, jnp.zeros((b,), jnp.int32), kv,
        cfg=cfg, use_pallas=use_pallas,
    )
    return logits, feats
