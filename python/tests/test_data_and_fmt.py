"""Corpus generators (task structure, determinism) and the FEW1 weights
format roundtrip."""

import os
import random
import tempfile

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import data
from compile.configs import TASKS
from compile.fmt import read_weights, write_weights


def test_generators_are_deterministic():
    a = data.corpus(10, (1, 1, 1, 1, 1), 7)
    b = data.corpus(10, (1, 1, 1, 1, 1), 7)
    assert a == b
    c = data.corpus(10, (1, 1, 1, 1, 1), 8)
    assert a != c


def test_every_task_produces_prompt_and_response():
    rng = random.Random(0)
    for task in TASKS:
        p, r = data.gen_example(task, rng)
        assert len(p) > 10 and len(r) > 5, task
        assert p.isascii() and r.isascii(), task


def test_task_structure_markers():
    rng = random.Random(1)
    assert "ASSISTANT:" in data.gen_dialog(rng)[0]
    assert data.gen_code(rng)[0].startswith("# task:")
    assert "def " in data.gen_code(rng)[0]
    q, a = data.gen_math(rng)
    assert "Q:" in q and "answer is" in a
    assert "### Instruction" in data.gen_inst(rng)[0]
    assert "TL;DR:" in data.gen_news(rng)[0]


def test_math_arithmetic_is_correct():
    rng = random.Random(2)
    for _ in range(50):
        q, a = data.gen_math(rng)
        # "... has {n1} ... buys {n2} ... = {s} ..."
        nums = [int(t) for t in q.replace("?", " ").split() if t.isdigit()]
        total = [int(t) for t in a.replace(".", " ").split() if t.isdigit()][-1]
        assert nums[0] + nums[1] == total


def test_mixture_skews_task_frequency():
    math_heavy = data.corpus(300, (0.1, 0.1, 5.0, 0.1, 0.1), 3)
    frac = sum("answer is" in t for t in math_heavy) / len(math_heavy)
    assert frac > 0.7, frac


def test_eval_prompts_disjoint_seed_space():
    train_texts = set(data.corpus(200, (1, 1, 1, 1, 1), 0))
    evals = data.eval_prompts("dialog", 32)
    # eval prompts are prompt-prefixes; at minimum they must not be
    # verbatim members of the train corpus
    assert not any(e in train_texts for e in evals)


def test_encode_decode_roundtrip():
    s = "hello WORLD 123\n"
    assert data.decode(data.encode(s)) == s


@settings(max_examples=20, deadline=None)
@given(
    tensors=st.lists(
        st.tuples(
            st.text(st.characters(min_codepoint=97, max_codepoint=122),
                    min_size=1, max_size=20),
            st.lists(st.integers(1, 5), min_size=0, max_size=3),
        ),
        min_size=1,
        max_size=5,
        unique_by=lambda x: x[0],
    )
)
def test_few1_roundtrip(tensors):
    rng = np.random.default_rng(0)
    named = [(name, rng.standard_normal(shape).astype(np.float32))
             for name, shape in tensors]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.few")
        write_weights(path, named)
        back = dict(read_weights(path))
        assert set(back) == {n for n, _ in named}
        for name, arr in named:
            np.testing.assert_array_equal(back[name], arr)


def test_few1_int32_tensors():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.few")
        write_weights(path, [("idx", np.array([1, -2, 3], np.int32))])
        back = dict(read_weights(path))
        assert back["idx"].dtype == np.int32
        np.testing.assert_array_equal(back["idx"], [1, -2, 3])
