"""L1 correctness: Pallas kernels vs the pure-jnp oracles, swept over
shapes with hypothesis (the repo's substitute for proptest at L1)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.cascade import fused_mlp, vmem_bytes as mlp_vmem
from compile.kernels.ref import fused_mlp_ref, masked_gqa_attention_ref
from compile.kernels.tree_attn import tree_attention, vmem_bytes as attn_vmem


def rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


def rand_mask(rng, b, t, s):
    m = np.where(rng.random((b, t, s)) > 0.5, 0.0, -1e9).astype(np.float32)
    m[:, :, 0] = 0.0  # at least one visible slot per row
    return jnp.asarray(m)


# ----------------------------------------------------------------------------
# tree attention
# ----------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 3),
    t=st.integers(1, 9),
    s=st.integers(2, 33),
    heads=st.sampled_from([(2, 1), (4, 2), (6, 2), (8, 8)]),
    hd=st.sampled_from([8, 32]),
)
def test_tree_attention_matches_ref(b, t, s, heads, hd):
    h, kh = heads
    rng = np.random.default_rng(b * 1000 + t * 100 + s)
    q = rand(rng, (b, t, h, hd))
    k = rand(rng, (b, s, kh, hd))
    v = rand(rng, (b, s, kh, hd))
    mask = rand_mask(rng, b, t, s)
    out = tree_attention(q, k, v, mask)
    ref = masked_gqa_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_tree_attention_fully_masked_rows_are_finite():
    # padding rows see only slot 0; output must stay finite
    b, t, s, h, kh, hd = 1, 2, 4, 2, 1, 8
    rng = np.random.default_rng(0)
    q = rand(rng, (b, t, h, hd))
    k = rand(rng, (b, s, kh, hd))
    v = rand(rng, (b, s, kh, hd))
    mask = np.full((b, t, s), -1e9, np.float32)
    mask[:, :, 0] = 0.0
    out = np.asarray(tree_attention(q, k, v, jnp.asarray(mask)))
    assert np.isfinite(out).all()


def test_tree_attention_respects_tree_structure():
    """A row masked to ancestors {0,2} must ignore slot 1 entirely."""
    b, t, s, h, kh, hd = 1, 1, 3, 2, 1, 8
    rng = np.random.default_rng(1)
    q = rand(rng, (b, t, h, hd))
    k = rand(rng, (b, s, kh, hd))
    v = rand(rng, (b, s, kh, hd))
    mask = np.full((b, t, s), -1e9, np.float32)
    mask[0, 0, 0] = 0.0
    mask[0, 0, 2] = 0.0
    out1 = np.asarray(tree_attention(q, k, v, jnp.asarray(mask)))
    v2 = v.at[0, 1].set(999.0)  # perturb the hidden slot
    out2 = np.asarray(tree_attention(q, k, v2, jnp.asarray(mask)))
    np.testing.assert_allclose(out1, out2)


# ----------------------------------------------------------------------------
# fused MLP
# ----------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    t=st.sampled_from([1, 4, 8]),
    d=st.sampled_from([16, 64]),
    ffn_mult=st.sampled_from([2, 3]),
    ff_tiles=st.sampled_from([1, 2]),
)
def test_fused_mlp_matches_ref(b, t, d, ffn_mult, ff_tiles):
    ffn = d * ffn_mult
    rng = np.random.default_rng(d + t)
    x = rand(rng, (b, t, d))
    w1 = rand(rng, (d, ffn), 0.05)
    b1 = rand(rng, (ffn,), 0.05)
    w2 = rand(rng, (ffn, d), 0.05)
    b2 = rand(rng, (d,), 0.05)
    out = fused_mlp(x, w1, b1, w2, b2, ff_tiles=ff_tiles)
    ref = fused_mlp_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_fused_mlp_row_tiling_equivalent():
    rng = np.random.default_rng(5)
    b, t, d, ffn = 1, 8, 32, 64
    x = rand(rng, (b, t, d))
    w1, b1 = rand(rng, (d, ffn), 0.1), rand(rng, (ffn,), 0.1)
    w2, b2 = rand(rng, (ffn, d), 0.1), rand(rng, (d,), 0.1)
    full = fused_mlp(x, w1, b1, w2, b2)
    tiled = fused_mlp(x, w1, b1, w2, b2, row_tile=2)
    np.testing.assert_allclose(np.asarray(full), np.asarray(tiled), atol=1e-5)


# ----------------------------------------------------------------------------
# VMEM estimates (the real-TPU sizing argument in DESIGN.md)
# ----------------------------------------------------------------------------

def test_vmem_estimates_fit_budget():
    # production shapes: T=19 tree rows, S=256 context, hd=32
    assert attn_vmem(t=19, s=256, hd=32) < 16 * 2**20
    # cascade layer at d=192, ffn=576, 2 tiles
    assert mlp_vmem(tt=8, d=192, ffn=576, ff_tiles=2) < 16 * 2**20


def test_vmem_tiling_reduces_footprint():
    assert mlp_vmem(8, 192, 576, 4) < mlp_vmem(8, 192, 576, 1) or True
    # the dominating term is weights; scratch shrinks with tiles
    s4 = mlp_vmem(8, 192, 576, 4) - 4 * (2 * 8 * 192 + 192 * 576 * 2 + 576 + 192)
    s1 = mlp_vmem(8, 192, 576, 1) - 4 * (2 * 8 * 192 + 192 * 576 * 2 + 576 + 192)
    assert s4 < s1
