"""Training objective (§2.3) unit tests: losses, AdamW, frozen params,
and loss-decrease smoke runs at tiny scale."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, train
from compile.configs import DrafterConfig, PAD, TargetConfig, TrainConfig

TINY = TargetConfig(
    name="tiny", stands_for="test", d_model=32, n_layers=3, n_heads=2,
    n_kv_heads=1, head_dim=16, ffn=64, taps=(0, 1, 2), max_seq=64,
)
TC = TrainConfig(seq_len=32, batch=4, target_steps=8, drafter_steps=6,
                 n_train_seqs=16)


def test_smooth_l1_matches_paper_eq6():
    x = jnp.array([-2.0, -0.5, 0.0, 0.5, 2.0])
    out = np.asarray(train.smooth_l1(x))
    np.testing.assert_allclose(out, [1.5, 0.125, 0.0, 0.125, 1.5])


def test_soft_ce_minimized_at_teacher():
    teacher = jnp.array([[2.0, 0.0, -1.0]])
    valid = jnp.ones((1,))
    at_teacher = float(train.soft_ce(teacher, teacher, valid))
    off = float(train.soft_ce(jnp.array([[0.0, 2.0, 0.0]]), teacher, valid))
    assert at_teacher < off


def test_layer_weights_follow_decay():
    w = train._layer_weights(6, 0.9)
    # w_i = 0.9^{N-i}: deepest layer weighted 0.9^0 = 1
    np.testing.assert_allclose(w[-1], 1.0)
    np.testing.assert_allclose(w[0], 0.9**5, rtol=1e-6)
    assert (np.diff(w) > 0).all()


def test_adamw_moves_params_and_respects_frozen():
    params = {"a": jnp.ones(3), "emb": jnp.ones(3)}
    grads = {"a": jnp.ones(3), "emb": jnp.ones(3)}
    st = train.adamw_init(params)
    new, st2 = train.adamw_update(params, grads, st, lr=0.1,
                                  tc=TC, frozen=("emb",))
    assert not np.allclose(np.asarray(new["a"]), 1.0)
    np.testing.assert_allclose(np.asarray(new["emb"]), 1.0)
    assert int(st2["t"]) == 1


def test_grad_clip_bounds_update():
    params = {"a": jnp.zeros(4)}
    huge = {"a": jnp.full(4, 1e6)}
    st = train.adamw_init(params)
    new, _ = train.adamw_update(params, huge, st, lr=1.0, tc=TC)
    # first-step Adam update magnitude is ~lr regardless of grad scale,
    # but clipping must have prevented inf/nan
    assert np.isfinite(np.asarray(new["a"])).all()


def test_tokenize_corpus_shape_and_padding():
    toks = train.tokenize_corpus(["ab", "x" * 100], 16)
    assert toks.shape == (2, 17)
    assert toks[0, 0] == 256  # BOS
    assert (toks[0, 3:] == PAD).all()
    assert (toks[1] != PAD).all()


@pytest.fixture(scope="module")
def trained():
    texts = data.corpus(TC.n_train_seqs, (1, 1, 1, 1, 1), 0)
    toks = train.tokenize_corpus(texts, TC.seq_len)
    params, losses = train.train_target(TINY, TC, toks, lambda s: None)
    tl, tf = train.harvest(TINY, params, toks)
    return toks, params, losses, tl, tf


def test_target_loss_decreases(trained):
    _, _, losses, _, _ = trained
    assert losses[-1] < losses[0]


def test_harvest_shapes(trained):
    toks, _, _, tl, tf = trained
    n, t1 = toks.shape
    assert tl.shape == (n, t1 - 1, TINY.vocab)
    assert tf.shape == (n, t1 - 1, 3 * TINY.d_model)


def test_fasteagle_training_decreases(trained):
    toks, params, _, tl, tf = trained
    _, losses = train.train_fasteagle(
        TINY, DrafterConfig("fasteagle", "fasteagle"), TC, params, toks, tl, tf,
        lambda s: None)
    assert losses[-1] < losses[0]


def test_eagle_training_variants(trained):
    toks, params, _, tl, tf = trained
    for dc in [DrafterConfig("eagle3", "eagle"),
               DrafterConfig("eagle2", "eagle", multi_level=False, rollout=False)]:
        _, losses = train.train_eagle(TINY, dc, TC, params, toks, tl, tf,
                                      lambda s: None)
        assert losses[-1] < losses[0], dc.name


def test_nofeat_ablation_trains_without_feature_loss(trained):
    toks, params, _, tl, tf = trained
    dc = DrafterConfig("fasteagle_nofeat", "fasteagle", feature_loss=False)
    _, losses = train.train_fasteagle(TINY, dc, TC, params, toks, tl, tf,
                                      lambda s: None)
    # CE-only: starts at ~ln(V)*sum(w_i) ~= 26 and decreases
    assert losses[0] < 40.0
    assert losses[-1] < losses[0]
