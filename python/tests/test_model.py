"""L2 model semantics: incremental (KV-cached, masked) execution must
match the full-causal teacher pass; drafter shapes and the scatter-rows
primitive; pallas vs jnp paths agree end-to-end."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import BOS, DRAFT_DEPTH, TARGETS, TargetConfig
from compile.drafters import (eg_apply, eg_kv_shape, fe_apply, fe_kv_shape,
                              init_eagle, init_fasteagle, init_medusa,
                              medusa_apply)
from compile.layers import causal_mask, scatter_rows
from compile.model import init_target, kv_shape, target_apply, target_train_apply

TINY = TargetConfig(
    name="tiny", stands_for="test", d_model=32, n_layers=3, n_heads=2,
    n_kv_heads=1, head_dim=16, ffn=64, taps=(0, 1, 2), max_seq=32,
)


@pytest.fixture(scope="module")
def params():
    return init_target(jax.random.PRNGKey(0), TINY)


def neg_mask(b, t, s):
    return np.full((b, t, s), -1e9, np.float32)


def test_scatter_rows_per_batch_offsets():
    cache = jnp.zeros((2, 6, 1, 2))
    new = jnp.ones((2, 2, 1, 2)) * jnp.array([1.0, 2.0])[:, None, None, None]
    out = scatter_rows(cache, new, jnp.array([1, 3], jnp.int32))
    out = np.asarray(out)
    assert (out[0, 1:3] == 1.0).all() and (out[0, 0] == 0).all() and (out[0, 3:] == 0).all()
    assert (out[1, 3:5] == 2.0).all() and (out[1, :3] == 0).all() and (out[1, 5] == 0).all()


def test_incremental_matches_full(params):
    """Chunked prefill (3+2 tokens) == full causal pass — the contract the
    Rust engine relies on for losslessness."""
    tokens = jnp.array([[BOS, 10, 20, 30, 40]], jnp.int32)
    full_logits, full_feats = target_train_apply(params, tokens, cfg=TINY)

    s = TINY.max_seq
    kv = jnp.zeros(kv_shape(TINY, 1, s), jnp.float32)
    outs = []
    feats = []
    base = 0
    for chunk in [tokens[:, :3], tokens[:, 3:]]:
        t = chunk.shape[1]
        mask = neg_mask(1, t, s)
        for i in range(t):
            mask[0, i, : base + i + 1] = 0.0
        pos = jnp.arange(base, base + t, dtype=jnp.int32)[None]
        logits, f, kv = target_apply(
            params, chunk, pos, jnp.asarray(mask),
            jnp.array([base], jnp.int32), kv, cfg=TINY, use_pallas=False)
        outs.append(np.asarray(logits))
        feats.append(np.asarray(f))
        base += t
    inc_logits = np.concatenate(outs, axis=1)
    np.testing.assert_allclose(inc_logits, np.asarray(full_logits), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(
        np.concatenate(feats, axis=1), np.asarray(full_feats), atol=2e-4, rtol=2e-4)


def test_tree_rows_match_sequential(params):
    """A chain verified as parallel rows (with ancestor masks) must produce
    the same logits as feeding the tokens one at a time."""
    prompt = jnp.array([[BOS, 5, 6]], jnp.int32)
    s = TINY.max_seq
    kv = jnp.zeros(kv_shape(TINY, 1, s), jnp.float32)
    mask = neg_mask(1, 3, s)
    for i in range(3):
        mask[0, i, : i + 1] = 0.0
    _, _, kv = target_apply(params, prompt, jnp.arange(3, dtype=jnp.int32)[None],
                            jnp.asarray(mask), jnp.array([0], jnp.int32), kv,
                            cfg=TINY, use_pallas=False)
    chain = [7, 8, 9]
    # parallel: 3 rows at slots 3,4,5 with ancestor masks
    m = neg_mask(1, 3, s)
    for i in range(3):
        m[0, i, :3] = 0.0  # prefix
        for j in range(i + 1):
            m[0, i, 3 + j] = 0.0  # ancestors incl self
    lp, _, _ = target_apply(
        params, jnp.array([chain], jnp.int32),
        jnp.array([[3, 4, 5]], jnp.int32), jnp.asarray(m),
        jnp.array([3], jnp.int32), kv, cfg=TINY, use_pallas=False)
    # sequential
    kv_seq = kv
    seq_logits = []
    for i, tok in enumerate(chain):
        m1 = neg_mask(1, 1, s)
        m1[0, 0, : 3 + i + 1] = 0.0
        l, _, kv_seq = target_apply(
            params, jnp.array([[tok]], jnp.int32),
            jnp.array([[3 + i]], jnp.int32), jnp.asarray(m1),
            jnp.array([3 + i], jnp.int32), kv_seq,
            cfg=TINY, use_pallas=False)
        seq_logits.append(np.asarray(l)[0, 0])
    np.testing.assert_allclose(
        np.asarray(lp)[0], np.stack(seq_logits), atol=2e-4, rtol=2e-4)


def test_pallas_and_ref_paths_agree(params):
    tokens = jnp.array([[BOS, 1, 2, 3]], jnp.int32)
    lp, fp = target_train_apply(params, tokens, cfg=TINY, use_pallas=True)
    lr, fr = target_train_apply(params, tokens, cfg=TINY, use_pallas=False)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lr), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(fp), np.asarray(fr), atol=1e-4, rtol=1e-4)


def test_fasteagle_shapes_and_parallel_ablation(params):
    fe = init_fasteagle(jax.random.PRNGKey(1), TINY, params["emb"], n_cascade=4)
    b, t, c = 2, 5, TINY.max_seq
    feats = jnp.zeros((b, t, 3 * TINY.d_model))
    toks = jnp.zeros((b, t), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    mask = causal_mask(b, t, c)
    dkv = jnp.zeros(fe_kv_shape(TINY, b, n_cascade=4), jnp.float32)
    logits, hidden, dkv2 = fe_apply(
        fe, feats, toks, pos, mask, jnp.zeros((b,), jnp.int32), dkv,
        cfg=TINY, n_cascade=4, use_pallas=False)
    assert logits.shape == (b, t, 4, TINY.vocab)
    assert hidden.shape == (b, t, 4, TINY.d_model)
    assert dkv2.shape == dkv.shape
    # parallel ablation differs from cascade beyond layer 1
    lp, _, _ = fe_apply(
        fe, feats, toks, pos, mask, jnp.zeros((b,), jnp.int32), dkv,
        cfg=TINY, n_cascade=4, parallel=True, use_pallas=False)
    np.testing.assert_allclose(
        np.asarray(logits[:, :, 0]), np.asarray(lp[:, :, 0]), atol=1e-5)
    assert not np.allclose(np.asarray(logits[:, :, 1]), np.asarray(lp[:, :, 1]))


def test_eagle_first_vs_next_shapes(params):
    eg = init_eagle(jax.random.PRNGKey(2), TINY, params["emb"], multi_level=True)
    b, t, c = 1, 3, TINY.max_seq
    mask = causal_mask(b, t, c)
    ekv = jnp.zeros(eg_kv_shape(TINY, b), jnp.float32)
    feats = jnp.zeros((b, t, 3 * TINY.d_model))
    toks = jnp.zeros((b, t), jnp.int32)
    pos = jnp.zeros((b, t), jnp.int32)
    logits, h, ekv2 = eg_apply(eg, feats, toks, pos, mask,
                               jnp.zeros((b,), jnp.int32), ekv,
                               cfg=TINY, first=True, use_pallas=False)
    assert logits.shape == (b, t, TINY.vocab)
    assert h.shape == (b, t, TINY.d_model)
    # next-step consumes h
    l2, h2, _ = eg_apply(eg, h, toks, pos, mask, jnp.zeros((b,), jnp.int32),
                         ekv2, cfg=TINY, first=False, use_pallas=False)
    assert l2.shape == (b, t, TINY.vocab)
    assert h2.shape == h.shape


def test_medusa_heads_shape(params):
    md = init_medusa(jax.random.PRNGKey(3), TINY, params["emb"])
    out = medusa_apply(md, jnp.zeros((1, 1, 3 * TINY.d_model)))
    assert out.shape == (1, 1, 4, TINY.vocab)


def test_configs_are_consistent():
    for cfg in TARGETS.values():
        assert cfg.n_heads * cfg.head_dim == cfg.d_model
        assert len(cfg.taps) == 3
        assert max(cfg.taps) == cfg.n_layers - 1
        assert cfg.vocab % 16 == 0
