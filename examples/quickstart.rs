//! Quickstart: load the AOT artifacts, generate with the FastEagle
//! drafter, and compare against vanilla autoregressive decoding on the
//! same prompt — the 30-second tour of the whole stack.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::rc::Rc;
use std::sync::Arc;

use fasteagle::draft::make_drafter;
use fasteagle::model::TargetModel;
use fasteagle::runtime::{ArtifactStore, Runtime};
use fasteagle::spec::{Engine, GenConfig};

fn main() -> anyhow::Result<()> {
    let root = std::env::var("FE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = Arc::new(Runtime::from_env()?);
    let store = Rc::new(ArtifactStore::open(rt, format!("{root}/base").into())?);

    let prompt = "Q: Ana has 12 apples and buys 7 more apples. how many apples does Ana have?\nA:";
    let cfg = GenConfig { max_new_tokens: 48, ..Default::default() };

    // vanilla baseline
    let target = TargetModel::open(Rc::clone(&store))?;
    let mut vanilla = Engine::new(target, make_drafter(Rc::clone(&store), "vanilla")?);
    vanilla.generate(prompt, &cfg)?; // warm the executables
    let v = vanilla.generate(prompt, &cfg)?;

    // FastEagle: entire draft in a single drafter pass per cycle
    let target = TargetModel::open(Rc::clone(&store))?;
    let mut fe = Engine::new(target, make_drafter(Rc::clone(&store), "fasteagle")?);
    fe.generate(prompt, &cfg)?; // warm
    let f = fe.generate(prompt, &cfg)?;

    println!("prompt:    {prompt:?}");
    println!("output:    {:?}", f.text);
    println!();
    println!(
        "vanilla:   {:>6.1} tok/s  ({} target forwards)",
        v.metrics.tokens_per_sec(),
        v.metrics.cycles
    );
    println!(
        "fasteagle: {:>6.1} tok/s  ({} verification cycles, tau={:.2})",
        f.metrics.tokens_per_sec(),
        f.metrics.cycles,
        f.metrics.tau()
    );
    println!(
        "speedup:   {:.2}x   lossless: {}",
        f.metrics.tokens_per_sec() / v.metrics.tokens_per_sec(),
        if f.tokens == v.tokens { "yes (greedy outputs identical)" } else { "NO" }
    );
    println!("\nphase breakdown (fasteagle):\n{}", f.metrics.timer.report());
    Ok(())
}
