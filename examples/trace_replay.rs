//! Bursty-trace replay through the continuous batcher: demonstrates
//! admission control under a KV block budget (requests queue when the
//! pool is exhausted) and compares FastEagle vs vanilla throughput on
//! the same burst.
//!
//!   cargo run --release --example trace_replay

use std::rc::Rc;
use std::sync::Arc;
use std::time::Duration;

use fasteagle::coordinator::{BatchConfig, BatchEngine, BatchMethod, Request};
use fasteagle::runtime::{ArtifactStore, Runtime};
use fasteagle::workload;

fn main() -> anyhow::Result<()> {
    let root = std::env::var("FE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    // prefer the "mid" target (it has batched executables); fall back to base@b1
    let (target, batch) = if std::path::Path::new(&format!("{root}/mid/spec.json")).exists()
    {
        ("mid", 4)
    } else {
        ("base", 1)
    };
    let rt = Arc::new(Runtime::cpu()?);
    let store = Rc::new(ArtifactStore::open(rt, format!("{root}/{target}").into())?);
    let prompts = workload::load_prompts(std::path::Path::new(&root), "inst")?;
    let trace = workload::bursty_trace(&prompts, 2, batch * 2, Duration::from_millis(200), 32, 7);
    println!("trace: {} requests in 2 bursts, target={target}, batch={batch}", trace.len());

    for method in [BatchMethod::Vanilla, BatchMethod::FastEagle] {
        let mut cfg = BatchConfig::new(batch, method);
        cfg.chain_len = 2;
        // a deliberately tight block budget: half the burst fits at once
        let probe = fasteagle::model::BlockPool::new(1, cfg.block_slots);
        let spec = fasteagle::model::ModelSpec::parse(&store.spec_json()?)?;
        let per_req = probe.blocks_for(
            spec.max_seq,
            spec.n_layers + method.drafter_kv_layers(&spec),
        );
        cfg.pool_blocks = Some(per_req * batch.max(2));
        let mut eng = BatchEngine::new(Rc::clone(&store), cfg)?;
        let reqs: Vec<Request> = trace
            .iter()
            .enumerate()
            .map(|(i, it)| {
                let mut r = Request::new(i as u64, it.prompt.clone());
                r.cfg.max_new_tokens = it.max_new;
                r
            })
            .collect();
        // warm executables out of the measurement
        {
            let mut w = Request::new(999, trace[0].prompt.clone());
            w.cfg.max_new_tokens = 4;
            let _ = eng.run(vec![w])?;
        }
        let t0 = std::time::Instant::now();
        let (resps, m) = eng.run(reqs)?;
        let toks: usize = resps.iter().map(|r| r.new_tokens).sum();
        println!(
            "  {:>9}: {} done, {:.1} tok/s, tau={:.2}, pool_blocks={:?}",
            method.name(),
            resps.len(),
            toks as f64 / t0.elapsed().as_secs_f64(),
            m.mean_tau(),
            per_req * batch.max(2),
        );
    }
    Ok(())
}
