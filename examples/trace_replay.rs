//! Bursty-trace replay through the continuous batcher's step() loop
//! (`workload::replay_trace`, the same scheduler the TCP server
//! drives): demonstrates admission control under a KV block budget
//! (requests defer when the pool is exhausted, counted once each) and
//! compares FastEagle vs vanilla latency and scheduler pressure on the
//! same burst.
//!
//!   cargo run --release --example trace_replay

use std::rc::Rc;
use std::sync::Arc;
use std::time::Duration;

use fasteagle::coordinator::{BatchConfig, BatchEngine, BatchMethod, Request};
use fasteagle::runtime::{ArtifactStore, Runtime};
use fasteagle::workload;

fn main() -> anyhow::Result<()> {
    let root = std::env::var("FE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    // prefer the "mid" target (it has batched executables); fall back to base@b1
    let (target, batch) = if std::path::Path::new(&format!("{root}/mid/spec.json")).exists()
    {
        ("mid", 4)
    } else {
        ("base", 1)
    };
    let rt = Arc::new(Runtime::from_env()?);
    let store = Rc::new(ArtifactStore::open(rt, format!("{root}/{target}").into())?);
    let prompts = workload::load_prompts(std::path::Path::new(&root), "inst")?;
    let trace = workload::bursty_trace(&prompts, 2, batch * 2, Duration::from_millis(200), 32, 7);
    println!("trace: {} requests in 2 bursts, target={target}, batch={batch}", trace.len());

    for method in [BatchMethod::Vanilla, BatchMethod::FastEagle] {
        let mut cfg = BatchConfig::new(batch, method);
        cfg.chain_len = 2;
        // a deliberately tight block budget: half the burst fits at once
        let probe = fasteagle::model::BlockPool::new(1, cfg.block_slots);
        let spec = fasteagle::model::ModelSpec::parse(&store.spec_json()?)?;
        let per_req = probe.blocks_for(
            spec.max_seq,
            spec.n_layers + method.drafter_kv_layers(&spec),
        );
        cfg.pool_blocks = Some(per_req * batch.max(2));
        let mut eng = BatchEngine::new(Rc::clone(&store), cfg)?;
        // warm executables out of the measurement
        {
            let mut w = Request::new(999, trace[0].prompt.clone());
            w.cfg.max_new_tokens = 4;
            let _ = eng.run(vec![w])?;
        }
        let t0 = std::time::Instant::now();
        let (resps, m) = workload::replay_trace(&mut eng, &trace, 0)?;
        let toks: usize = resps.iter().map(|r| r.new_tokens).sum();
        // open-loop numbers: the wall clock includes the arrival gaps,
        // which are identical for every method — compare p50 latency and
        // occupancy/deferred pressure rather than raw tok/s
        println!(
            "  {:>9}: {} done, {:.1} tok/s open-loop, p50={:.0}ms, tau={:.2}, \
             occ={:.2}, deferred={}, pool_blocks={:?}",
            method.name(),
            resps.len(),
            toks as f64 / t0.elapsed().as_secs_f64(),
            m.latency.percentile_us(0.5) / 1e3,
            m.mean_tau(),
            m.mean_occupancy(),
            m.requests_deferred,
            per_req * batch.max(2),
        );
    }
    Ok(())
}
