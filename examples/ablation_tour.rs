//! Ablation tour (paper §3.2 / Table 2 on one prompt): walk the three
//! FastEagle ablations and print how τ and speedup degrade as each
//! component is removed — the constrained tree, the cascade, and the
//! feature-alignment loss.

use std::rc::Rc;
use std::sync::Arc;

use fasteagle::draft::make_drafter;
use fasteagle::model::TargetModel;
use fasteagle::runtime::{ArtifactStore, Runtime};
use fasteagle::spec::{DraftConfig, Engine, GenConfig};

fn main() -> anyhow::Result<()> {
    let root = std::env::var("FE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = Arc::new(Runtime::from_env()?);
    let store = Rc::new(ArtifactStore::open(rt, format!("{root}/base").into())?);
    let prompt =
        "USER: tell me about healthy food and the quiet garden.\nASSISTANT:";

    let variants: [(&str, &str, bool); 4] = [
        ("Full (cascade + tree + feat loss)", "fasteagle", true),
        ("w/o Constrained Tree (chain)", "fasteagle", false),
        ("w/o Cascaded Structure (parallel)", "fasteagle_par", true),
        ("w/o Feature Loss (CE only)", "fasteagle_nofeat", true),
    ];

    // vanilla reference for speedups
    let target = TargetModel::open(Rc::clone(&store))?;
    let mut vanilla = Engine::new(target, make_drafter(Rc::clone(&store), "vanilla")?);
    let cfg = GenConfig { max_new_tokens: 48, ..Default::default() };
    vanilla.generate(prompt, &cfg)?;
    let v = vanilla.generate(prompt, &cfg)?;
    println!("vanilla reference: {:.1} tok/s\n", v.metrics.tokens_per_sec());

    for (label, wset, use_tree) in variants {
        let target = TargetModel::open(Rc::clone(&store))?;
        let mut eng = Engine::new(target, make_drafter(Rc::clone(&store), wset)?);
        let top_k = if use_tree { None } else { Some(1) };
        let cfg = GenConfig {
            max_new_tokens: 48,
            draft: DraftConfig { top_k, ..Default::default() },
            ..Default::default()
        };
        eng.generate(prompt, &cfg)?; // warm
        let r = eng.generate(prompt, &cfg)?;
        println!(
            "{label:<36} tau={:.2}  speedup={:.2}x  lossless={}",
            r.metrics.tau(),
            r.metrics.tokens_per_sec() / v.metrics.tokens_per_sec(),
            r.tokens == v.tokens,
        );
    }
    Ok(())
}
