//! End-to-end serving driver (the EXPERIMENTS.md validation run): start
//! the TCP JSON-lines server over the continuous batcher, drive it with
//! concurrent clients replaying a Poisson arrival trace, and report
//! latency/throughput — proving all three layers compose on a real
//! (small) serving workload. When the "mid" target (which has batched
//! executables) is built, the server decodes several requests
//! concurrently and replies out of admission order.
//!
//!   cargo run --release --example serve_and_query -- [n_requests] [rate]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::rc::Rc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fasteagle::coordinator::{BatchConfig, BatchEngine, BatchMethod, Server, ServerConfig};
use fasteagle::runtime::{ArtifactStore, Runtime};
use fasteagle::util::json::Json;
use fasteagle::util::stats::summarize;
use fasteagle::workload;

const ADDR: &str = "127.0.0.1:7411";

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let n_requests: usize = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let rate: f64 = argv.get(2).and_then(|s| s.parse().ok()).unwrap_or(2.0);
    let root = std::env::var("FE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    // --- server thread (owns the engine) ---------------------------------
    let root2 = root.clone();
    let server_thread = std::thread::spawn(move || -> anyhow::Result<()> {
        // prefer the "mid" target when its spec lowers batched
        // executables, so the server actually serves batch > 1
        let (dir, batch) =
            workload::batched_serving_target(std::path::Path::new(&root2))
                .ok_or_else(|| anyhow::anyhow!("no serving target under {root2}"))?;
        let rt = Arc::new(Runtime::from_env()?);
        let store = Rc::new(ArtifactStore::open(rt, dir)?);
        let engine = BatchEngine::new(
            Rc::clone(&store),
            BatchConfig::new(batch, BatchMethod::FastEagle),
        )?;
        let server = Server::new(ServerConfig {
            addr: ADDR.into(),
            queue_capacity: 64,
            ..Default::default()
        });
        let m = server.serve(engine)?;
        eprintln!("[server] {}", m.report());
        Ok(())
    });

    // wait for the listener
    let mut up = false;
    for _ in 0..600 {
        if TcpStream::connect(ADDR).is_ok() {
            up = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    assert!(up, "server did not come up");

    // --- trace replay through concurrent clients -------------------------
    let prompts = workload::load_prompts(std::path::Path::new(&root), "dialog")?;
    let trace = workload::poisson_trace(&prompts, n_requests, rate, 48, 42);
    println!(
        "replaying {} requests (poisson {:.1} req/s) against {}",
        trace.len(),
        rate,
        ADDR
    );
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for item in trace {
        let h = std::thread::spawn(move || -> anyhow::Result<(f64, usize)> {
            let since = t0.elapsed();
            if item.at > since {
                std::thread::sleep(item.at - since);
            }
            let sent = Instant::now();
            let stream = TcpStream::connect(ADDR)?;
            let mut r = BufReader::new(stream.try_clone()?);
            let mut w = stream;
            let req = Json::obj(vec![
                ("prompt", Json::str(&item.prompt)),
                ("max_new", Json::num(item.max_new as f64)),
            ]);
            writeln!(w, "{}", req.to_string())?;
            let mut line = String::new();
            r.read_line(&mut line)?;
            let v = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("{e}"))?;
            let toks = v.get("new_tokens").and_then(Json::as_usize).unwrap_or(0);
            Ok((sent.elapsed().as_secs_f64() * 1e3, toks))
        });
        handles.push(h);
    }
    let mut latencies = Vec::new();
    let mut tokens = 0usize;
    for h in handles {
        let (ms, toks) = h.join().unwrap()?;
        latencies.push(ms);
        tokens += toks;
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = summarize(&latencies);
    println!("\n=== serve_and_query results ===");
    println!("requests: {}   total tokens: {tokens}   wall: {wall:.1}s", latencies.len());
    println!("throughput: {:.1} tok/s   {:.2} req/s", tokens as f64 / wall, latencies.len() as f64 / wall);
    println!("latency ms: p50={:.0} p90={:.0} p99={:.0} max={:.0}", s.p50, s.p90, s.p99, s.max);

    // --- streaming: per-cycle token frames over the same protocol -----
    // "stream": true opts into one {"event":"tokens",...} frame per
    // draft->verify->commit cycle before the final response.
    let conn = TcpStream::connect(ADDR)?;
    let mut w = conn.try_clone()?;
    let mut r = BufReader::new(conn);
    let req = Json::obj(vec![
        ("prompt", Json::str("USER: tell me about city transport and the steady bridge.\nASSISTANT:")),
        ("max_new", Json::num(32.0)),
        ("stream", Json::Bool(true)),
    ]);
    writeln!(w, "{}", req.to_string())?;
    let mut frames = 0usize;
    print!("\nstreaming: ");
    loop {
        let mut line = String::new();
        r.read_line(&mut line)?;
        let v = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("{e}"))?;
        if v.get("event").and_then(Json::as_str) == Some("tokens") {
            frames += 1;
            print!("{}", v.get("text").and_then(Json::as_str).unwrap_or(""));
            std::io::stdout().flush()?;
        } else {
            println!(
                "\nstreamed {} tokens over {frames} cycle frames (tau={:.2})",
                v.get("new_tokens").and_then(Json::as_usize).unwrap_or(0),
                v.get("tau").and_then(Json::as_f64).unwrap_or(0.0),
            );
            break;
        }
    }

    // shutdown
    let stream = TcpStream::connect(ADDR)?;
    let mut w = stream.try_clone()?;
    writeln!(w, "{}", Json::obj(vec![("cmd", Json::str("shutdown"))]).to_string())?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line)?;
    server_thread.join().unwrap()?;
    Ok(())
}
